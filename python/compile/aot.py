"""AOT-lower the L2 compute graphs to HLO *text* + a manifest for Rust.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --outdir ../artifacts

Writes one `<name>.hlo.txt` per entry point plus `manifest.json` describing
argument shapes/dtypes, output arity and a FLOP estimate per call, which
`rust/src/runtime/manifest.rs` consumes. Python runs exactly once, at build
time; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32, F64 = jnp.float32, jnp.float64


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# Fixed functional-mode shapes (see DESIGN.md §5): HPL tiles are padded to
# these by the Rust driver (zero-padding is exact for all four HPL ops).
NB = 64          # HPL block size
MLOC = 128       # HPL local tile edge
HPCG_N = 32      # HPCG local block edge
FFT_N = 32       # HACC local grid edge
NEK_E, NEK_P = 32, 9   # Nekbone: elements per call, poly order nx1=9


def _waxpby(x, y, ab):
    """waxpby with (2,)-packed scalars so Rust passes plain vec literals."""
    return model.hpcg_waxpby(ab[0], x, ab[1], y)


def _symgs(xp, r):
    return model.hpcg_symgs(xp, r, sweeps=1)


# name -> (fn, [arg specs], flops-per-call estimate)
REGISTRY = {
    "hpl_panel_factor": (
        model.hpl_panel_factor, [_spec((NB, NB), F64)], (2 / 3) * NB**3),
    "hpl_trsm_row": (
        model.hpl_trsm_row, [_spec((NB, NB), F64), _spec((NB, MLOC), F64)],
        NB * NB * MLOC),
    "hpl_trsm_col": (
        model.hpl_trsm_col, [_spec((NB, NB), F64), _spec((MLOC, NB), F64)],
        NB * NB * MLOC),
    "hpl_update": (
        model.hpl_update,
        [_spec((MLOC, NB), F64), _spec((NB, MLOC), F64),
         _spec((MLOC, MLOC), F64)],
        2 * MLOC * MLOC * NB),
    "hpl_residual": (
        model.hpl_residual,
        [_spec((4 * NB, 4 * NB), F64), _spec((4 * NB,), F64),
         _spec((4 * NB,), F64)],
        2 * (4 * NB) ** 2),
    "mxp_update": (
        model.mxp_update,
        [_spec((MLOC, NB), F32), _spec((NB, MLOC), F32),
         _spec((MLOC, MLOC), F32)],
        2 * MLOC * MLOC * NB),
    "mxp_ir_step": (
        model.mxp_ir_step,
        [_spec((4 * NB, 4 * NB), F64), _spec((4 * NB,), F64),
         _spec((4 * NB,), F64)],
        2 * (4 * NB) ** 2),
    "mxp_gemm": (
        lambda x, y: model.mxp_gemm(x, y),
        [_spec((256, 256), F32), _spec((256, 256), F32)],
        2 * 256**3),
    "hpcg_spmv": (
        model.hpcg_spmv, [_spec((HPCG_N + 2,) * 3, F32)],
        27 * 2 * HPCG_N**3),
    "hpcg_symgs": (
        _symgs, [_spec((HPCG_N + 2,) * 3, F32), _spec((HPCG_N,) * 3, F32)],
        2 * 27 * 2 * HPCG_N**3),
    "hpcg_dot": (
        model.hpcg_dot, [_spec((HPCG_N,) * 3, F32), _spec((HPCG_N,) * 3, F32)],
        2 * HPCG_N**3),
    "hpcg_waxpby": (
        _waxpby,
        [_spec((HPCG_N,) * 3, F32), _spec((HPCG_N,) * 3, F32),
         _spec((2,), F32)],
        3 * HPCG_N**3),
    "hacc_fft_poisson": (
        model.hacc_fft_poisson, [_spec((FFT_N,) * 3, F32)],
        5 * FFT_N**3 * (3 * 10) * 2),  # ~5 N^3 log2(N^3) per FFT, x2
    "hacc_short_range": (
        model.hacc_short_range, [_spec((256, 3), F32)], 20 * 256 * 256),
    "nekbone_ax": (
        model.nekbone_ax,
        [_spec((NEK_E, NEK_P, NEK_P, NEK_P), F64), _spec((NEK_P, NEK_P), F64)],
        12 * NEK_E * NEK_P**4),
    "lammps_pair_tile": (
        model.lammps_pair_tile, [_spec((128, 3), F32)], 30 * 128 * 128),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _n_outputs(fn, specs) -> int:
    out = jax.eval_shape(fn, *specs)
    return len(out) if isinstance(out, (tuple, list)) else 1


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--outdir", default="../artifacts")
    p.add_argument("--only", default=None,
                   help="comma-separated subset of artifact names")
    args = p.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    names = list(REGISTRY) if args.only is None else args.only.split(",")
    manifest = {}
    for name in names:
        fn, specs, flops = REGISTRY[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out = jax.eval_shape(fn, *specs)
        outs = list(out) if isinstance(out, (tuple, list)) else [out]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                     for s in specs],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                        for o in outs],
            "flops": float(flops),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {name}: {len(text)} chars -> {path}")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts + {mpath}")


if __name__ == "__main__":
    main()
