"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its oracle to tight tolerances
across the hypothesis shape/dtype sweep in python/tests/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .stencil27 import DIAG, OFF


def mxp_gemm_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """bf16 x bf16 -> f32 matmul, same rounding as the kernel."""
    return jnp.dot(x.astype(jnp.bfloat16), y.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)


def hpl_trailing_update_ref(a, b, c) -> jax.Array:
    f64 = jnp.float64
    return c.astype(f64) - jnp.dot(a.astype(f64), b.astype(f64))


def stencil27_ref(x_padded: jax.Array) -> jax.Array:
    nz, ny, nx = (d - 2 for d in x_padded.shape)
    acc = jnp.zeros((nz, ny, nx), x_padded.dtype)
    for dz in range(3):
        for dy in range(3):
            for dx in range(3):
                w = DIAG if (dz, dy, dx) == (1, 1, 1) else OFF
                acc = acc + w * x_padded[dz:dz + nz, dy:dy + ny, dx:dx + nx]
    return acc
