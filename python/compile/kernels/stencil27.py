"""27-point stencil SpMV — the HPCG local compute (paper §5.2.4).

HPCG's operator is the 27-point structured Laplacian: diag 26, all 26
neighbors -1, with boundary truncation. On GPUs this is a memory-bound
gather; on the TPU substrate we express it as a VPU-vectorized sum of 27
shifted slabs over a zero-padded block resident in VMEM.

The kernel takes the *padded* block (nz+2, ny+2, nx+2) and writes the
interior (nz, ny, nx). A multi-slab BlockSpec would need halo overlap which
Pallas block indexing cannot express directly; on real hardware the L3 MPI
halo exchange (rust `mpi::halo`) provides exactly those ghost layers, so the
single-block form matches the distributed decomposition: one rank's local
block per kernel invocation. VMEM: a 64^3 f32 padded block is ~1.1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: HPCG matrix coefficients: diagonal 26.0, every neighbor -1.0.
DIAG = 26.0
OFF = -1.0


def _stencil_kernel(xp_ref, o_ref):
    xp = xp_ref[...]
    nz, ny, nx = o_ref.shape
    # Sum of the 27 shifted views of the padded block; the (1,1,1) shift is
    # the center point, weighted DIAG, everything else OFF.
    acc = jnp.zeros((nz, ny, nx), xp.dtype)
    for dz in (0, 1, 2):
        for dy in (0, 1, 2):
            for dx in (0, 1, 2):
                w = DIAG if (dz, dy, dx) == (1, 1, 1) else OFF
                acc += w * jax.lax.dynamic_slice(xp, (dz, dy, dx), (nz, ny, nx))
    o_ref[...] = acc


@jax.jit
def stencil27(x_padded: jax.Array) -> jax.Array:
    """Apply the HPCG 27-pt operator to a padded block.

    x_padded: (nz+2, ny+2, nx+2) — ghost layers already filled (zeros on the
    physical boundary, halo-exchange data on interior subdomain faces).
    Returns (nz, ny, nx).
    """
    if x_padded.ndim != 3 or min(x_padded.shape) < 3:
        raise ValueError(f"padded block too small: {x_padded.shape}")
    nz, ny, nx = (d - 2 for d in x_padded.shape)
    return pl.pallas_call(
        _stencil_kernel,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), x_padded.dtype),
        interpret=True,
    )(x_padded)
