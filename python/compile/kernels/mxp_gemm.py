"""Mixed-precision blocked GEMM — the HPL-MxP hot spot (paper §5.2.2).

HPL-MxP factors in FP16/FP32 on the PVC matrix engines and refines in FP64.
On our TPU-shaped substrate the analogue is a bf16 x bf16 -> f32 MXU
contraction.  The kernel is a classic three-level blocked GEMM:

  grid = (M/bm, N/bn, K/bk)   -- K innermost so the f32 accumulator tile
                                 stays resident in VMEM across the K sweep
  x tile (bm, bk), y tile (bk, bn), out tile (bm, bn)

BlockSpec expresses the HBM->VMEM schedule the GPU code does with
workgroups/SLM staging; tiles default to 128x128 (MXU systolic array edge).

VMEM footprint per step (defaults, bf16 in / f32 acc):
  x 128x128x2 B + y 128x128x2 B + acc 128x128x4 B = 128 KiB  (<< 16 MiB VMEM)
so real-TPU double buffering of both input streams fits trivially; the MXU
sees one full 128x128x128 MACC block per grid step => structural utilization
is bounded by the K-sweep pipeline fill only (see DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mxp_gemm_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # bf16 x bf16 -> f32: preferred_element_type keeps the accumulator wide,
    # exactly the MXU mixed-precision contract (and the PVC XMX one).
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a: jax.Array, m: int, n: int) -> jax.Array:
    return jnp.pad(a, ((0, m - a.shape[0]), (0, n - a.shape[1])))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def mxp_gemm(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
             bk: int = 128) -> jax.Array:
    """C = A @ B with bf16 inputs and f32 accumulation.

    Accepts any float input dtype (cast to bf16 at the door — matching
    HPL-MxP's demotion of the FP64 problem into the low-precision factor);
    returns f32. Shapes need not be tile-aligned; we pad and slice.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"bad gemm shapes {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    bm, bk, bn = min(bm, _ceil_mult(m)), min(bk, _ceil_mult(k)), min(bn, _ceil_mult(n))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xb = _pad_to(x.astype(jnp.bfloat16), mp, kp)
    yb = _pad_to(y.astype(jnp.bfloat16), kp, np_)
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_mxp_gemm_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT target; Mosaic lowering is TPU-only.
    )(xb, yb)
    return out[:m, :n]


def _round_up(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def _ceil_mult(v: int) -> int:
    """Largest power-of-two tile edge <= 128 that is not absurd for tiny v."""
    e = 8
    while e < 128 and e < v:
        e *= 2
    return e
