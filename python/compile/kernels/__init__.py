"""Layer-1 Pallas kernels (build-time only; lowered with interpret=True).

Each kernel has a pure-jnp oracle in `ref.py`; pytest + hypothesis assert
allclose across shape/dtype sweeps. The kernels are written MXU/VMEM-shaped
(128-aligned BlockSpec tiles, bf16 x bf16 -> f32 contractions) per
DESIGN.md section "Hardware adaptation".
"""

from .mxp_gemm import mxp_gemm
from .hpl_update import hpl_trailing_update
from .stencil27 import stencil27

__all__ = ["mxp_gemm", "hpl_trailing_update", "stencil27"]
