"""FP64 blocked trailing-submatrix update — the HPL hot spot (paper §5.2.1).

Each HPL iteration applies C -= L_panel @ U_row to the trailing submatrix;
>90% of HPL runtime is this rank-nb update. Same three-level blocked
structure as mxp_gemm but in full FP64 (the Top500 run is pure FP64), with
the C tile loaded once, swept over K, and written back — i.e. a fused
"GEMM with beta=1, alpha=-1" rather than a separate add.

VMEM per step at (bm, bn, bk) = (128, 128, 128) in f64:
  a 128 KiB + b 128 KiB + c 128 KiB = 384 KiB, still deep inside VMEM;
on a real MXU part f64 is emulated (6-pass), which DESIGN.md §Perf accounts
for when translating the paper's PVC FP64 numbers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(a_ref, b_ref, c_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] -= jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float64)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def hpl_trailing_update(a: jax.Array, b: jax.Array, c: jax.Array, *,
                        bm: int = 128, bn: int = 128, bk: int = 64) -> jax.Array:
    """Return C - A @ B (f64). A: (m, nb), B: (nb, n), C: (m, n)."""
    if a.shape[0] != c.shape[0] or b.shape[1] != c.shape[1] \
            or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad update shapes {a.shape} {b.shape} {c.shape}")
    m, kdim = a.shape
    n = b.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    mp, np_, kp = _ru(m, bm), _ru(n, bn), _ru(kdim, bk)
    f64 = jnp.float64
    ap = jnp.pad(a.astype(f64), ((0, mp - m), (0, kp - kdim)))
    bp = jnp.pad(b.astype(f64), ((0, kp - kdim), (0, np_ - n)))
    cp = jnp.pad(c.astype(f64), ((0, mp - m), (0, np_ - n)))
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_update_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), f64),
        interpret=True,
    )(ap, bp, cp)
    return out[:m, :n]


def _ru(v: int, b: int) -> int:
    return (v + b - 1) // b * b
