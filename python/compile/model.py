"""Layer-2 JAX compute graphs — the per-node compute of the paper's workloads.

Each function here is the *local* (one simulated Aurora rank) compute step of
one benchmark from paper §5; the distributed structure (panel broadcasts,
halo exchanges, allreduces, RMA) lives in the Rust L3 coordinator, which
executes these graphs through PJRT from `artifacts/*.hlo.txt`.

All public entry points take/return plain f32/f64 arrays so the Rust side
never has to construct bf16/complex literals; precision conversion happens
inside the graph (matching how Cray MPICH hands host/GPU buffers to compute
libraries on Aurora).

HPL is decomposed exactly as a right-looking blocked LU needs on the grid:
  panel_factor -> trsm_row (U row strip) -> trailing update (L1 kernel).
No pivoting: the functional-mode driver feeds diagonally dominant matrices
(standard for LU-without-pivoting proxies; HPL's own correctness check is
the scaled residual, which we evaluate in hpl_residual).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import hpl_trailing_update, mxp_gemm, stencil27  # noqa: E402

# --------------------------------------------------------------------------
# HPL (paper §5.2.1, Fig 15, Table 2)
# --------------------------------------------------------------------------


def hpl_panel_factor(a: jax.Array) -> jax.Array:
    """Unpivoted LU of an (nb, nb) diagonal block; returns packed L\\U."""
    nb = a.shape[0]

    def body(k, m):
        col = m[:, k] / m[k, k]
        row_mask = jnp.arange(nb) > k
        l_col = jnp.where(row_mask, col, m[:, k])
        m = m.at[:, k].set(l_col)
        update = jnp.outer(jnp.where(row_mask, l_col, 0.0), m[k, :])
        col_mask = (jnp.arange(nb) > k)[None, :]
        return m - jnp.where(col_mask, update, 0.0)

    return jax.lax.fori_loop(0, nb - 1, body, a.astype(jnp.float64))


def hpl_trsm_row(lu: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L @ X = B for the U row strip. lu: packed (nb,nb), b: (nb,n).

    Explicit forward substitution via fori_loop: `solve_triangular` lowers
    to a TYPED_FFI custom call that the crate's xla_extension 0.5.1 cannot
    execute, so we emit pure HLO (see DESIGN.md §AOT).
    """
    nb = lu.shape[0]
    n = b.shape[1]
    l = jnp.tril(lu.astype(jnp.float64), -1)

    def body(k, x):
        row = jax.lax.dynamic_slice(x, (k, 0), (1, n))      # X[k, :]
        col = jax.lax.dynamic_slice(l, (0, k), (nb, 1))     # L[:, k]
        mask = (jnp.arange(nb) > k)[:, None]
        return x - jnp.where(mask, col @ row, 0.0)

    return jax.lax.fori_loop(0, nb, body, b.astype(jnp.float64))


def hpl_trsm_col(lu: jax.Array, a: jax.Array) -> jax.Array:
    """Solve X @ U = A for the L column strip. lu: (nb,nb), a: (m,nb).

    Column-by-column back substitution in pure HLO (same TYPED_FFI
    avoidance as hpl_trsm_row).
    """
    nb = lu.shape[0]
    m = a.shape[0]
    u = jnp.triu(lu.astype(jnp.float64))

    def body(k, x):
        ukk = jax.lax.dynamic_slice(u, (k, k), (1, 1))
        colk = jax.lax.dynamic_slice(x, (0, k), (m, 1)) / ukk
        x = jax.lax.dynamic_update_slice(x, colk, (0, k))
        urow = jax.lax.dynamic_slice(u, (k, 0), (1, nb))
        mask = (jnp.arange(nb) > k)[None, :]
        return x - jnp.where(mask, colk @ urow, 0.0)

    return jax.lax.fori_loop(0, nb, body, a.astype(jnp.float64))


def hpl_update(l_col: jax.Array, u_row: jax.Array, c: jax.Array) -> jax.Array:
    """Trailing update C -= L @ U via the L1 Pallas kernel."""
    return hpl_trailing_update(l_col, u_row, c)


def hpl_residual(a: jax.Array, x: jax.Array, b: jax.Array) -> jax.Array:
    """HPL-style scaled residual ||Ax-b||_inf / (||A||_inf ||x||_inf n eps)."""
    a = a.astype(jnp.float64)
    r = jnp.max(jnp.abs(a @ x - b))
    n = a.shape[0]
    eps = jnp.finfo(jnp.float64).eps
    return r / (jnp.max(jnp.sum(jnp.abs(a), axis=1)) *
                jnp.max(jnp.abs(x)) * n * eps)


# --------------------------------------------------------------------------
# HPL-MxP (paper §5.2.2, Fig 16): low-precision factor + FP64 IR
# --------------------------------------------------------------------------


def mxp_update(l_col: jax.Array, u_row: jax.Array, c: jax.Array) -> jax.Array:
    """Mixed-precision trailing update: C - A@B with bf16 MACCs, f32 out."""
    return c.astype(jnp.float32) - mxp_gemm(l_col, u_row)


def mxp_ir_step(a: jax.Array, x: jax.Array, b: jax.Array) -> tuple:
    """One FP64 iterative-refinement step: r = b - Ax (the IR hot loop).

    Returns (r, ||r||_inf). The correction solve reuses the low-precision
    factors on the Rust side; this graph is the FP64 residual evaluation.
    """
    a64 = a.astype(jnp.float64)
    r = b.astype(jnp.float64) - a64 @ x.astype(jnp.float64)
    return r, jnp.max(jnp.abs(r))


# --------------------------------------------------------------------------
# HPCG (paper §5.2.4): 27-pt CG with SymGS preconditioner, local ops
# --------------------------------------------------------------------------


def hpcg_spmv(x_padded: jax.Array) -> jax.Array:
    """Local SpMV through the L1 stencil kernel (ghosts pre-filled by L3)."""
    return stencil27(x_padded)


def hpcg_symgs(x_padded: jax.Array, r: jax.Array, sweeps: int = 1) -> jax.Array:
    """Damped-Jacobi stand-in for SymGS on the local block.

    HPCG's reference SymGS is sequential; multicolor/damped-Jacobi variants
    are the standard GPU substitution (same memory traffic, relaxed order).
    x_padded: (nz+2,ny+2,nx+2) current iterate with ghosts; r: (nz,ny,nx).
    """
    from .kernels.stencil27 import DIAG
    omega = 2.0 / 3.0
    nz, ny, nx = r.shape

    def body(_, xp):
        ax = stencil27(xp)
        xnew = xp[1:-1, 1:-1, 1:-1] + omega * (r - ax) / DIAG
        return xp.at[1:-1, 1:-1, 1:-1].set(xnew)

    return jax.lax.fori_loop(0, sweeps, body, x_padded)[1:-1, 1:-1, 1:-1]


def hpcg_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Local partial dot product (L3 allreduces the scalars)."""
    return jnp.sum(a.astype(jnp.float64) * b.astype(jnp.float64))


def hpcg_waxpby(alpha: jax.Array, x: jax.Array, beta: jax.Array,
                y: jax.Array) -> jax.Array:
    return alpha * x + beta * y


# --------------------------------------------------------------------------
# HACC (paper §5.3.1, Fig 17): long-range FFT step + short-range P^2 force
# --------------------------------------------------------------------------


def hacc_fft_poisson(rho: jax.Array) -> jax.Array:
    """Long-range force potential: FFT -> Green's function -> inverse FFT.

    rho: (n,n,n) f32 local density grid (the distributed pencil/slab
    decomposition and its all-to-all transposes are simulated at L3; this
    is the per-rank compute between transposes).
    """
    n = rho.shape[0]
    k = jnp.fft.fftfreq(n).astype(jnp.float32) * (2.0 * jnp.pi)
    kz, ky, kx = jnp.meshgrid(k, k, k, indexing="ij")
    k2 = kz * kz + ky * ky + kx * kx
    green = jnp.where(k2 > 0, -1.0 / jnp.maximum(k2, 1e-30), 0.0)
    phi_k = jnp.fft.fftn(rho.astype(jnp.complex64)) * green
    return jnp.real(jnp.fft.ifftn(phi_k)).astype(jnp.float32)


def hacc_short_range(pos: jax.Array, eps2: float = 1e-3) -> jax.Array:
    """O(p^2) short-range force kernel on a (p, 3) particle tile.

    The paper describes this phase as compute-intensive with stride-one
    access — an all-pairs softened gravity tile matches that profile.
    """
    d = pos[:, None, :] - pos[None, :, :]          # (p, p, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps2
    inv_r3 = r2 ** -1.5
    return jnp.sum(d * inv_r3[..., None], axis=1)  # (p, 3)


# --------------------------------------------------------------------------
# Nekbone (paper §5.3.2, Fig 18): spectral-element Ax + CG pieces
# --------------------------------------------------------------------------


def nekbone_ax(u: jax.Array, d: jax.Array) -> jax.Array:
    """Local spectral-element stiffness application.

    u: (E, n, n, n) element data, d: (n, n) 1-D derivative operator.
    w = D^T(D u) summed over the three tensor directions — the matrix-matrix
    backbone Nekbone spends its FLOPs on (small dense GEMMs).
    """
    ur = jnp.einsum("il,eljk->eijk", d, u)
    us = jnp.einsum("jl,eilk->eijk", d, u)
    ut = jnp.einsum("kl,eijl->eijk", d, u)
    return (jnp.einsum("li,eljk->eijk", d, ur)
            + jnp.einsum("lj,eilk->eijk", d, us)
            + jnp.einsum("lk,eijl->eijk", d, ut))


def nekbone_cg_local(u, r, p, ax, alpha, beta):
    """Fused CG vector updates (axpy group) for one iteration."""
    u = u + alpha * p
    r = r - alpha * ax
    p = r + beta * p
    return u, r, p


# --------------------------------------------------------------------------
# LAMMPS proxy (paper §5.3.4): LJ/CHARMM-style pair force on a tile
# --------------------------------------------------------------------------


def lammps_pair_tile(pos: jax.Array, cutoff2: float = 1.0) -> jax.Array:
    """Truncated 12-6 LJ force over an all-pairs tile (bin-local pairs).

    The 4x6x4 spatial binning from the paper lives at L3; each bin pair
    becomes one tile evaluation here.
    """
    d = pos[:, None, :] - pos[None, :, :]
    r2 = jnp.sum(d * d, axis=-1)
    mask = (r2 < cutoff2) & (r2 > 0)
    r2s = jnp.where(mask, r2, 1.0)
    inv6 = r2s ** -3
    fmag = jnp.where(mask, 24.0 * inv6 * (2.0 * inv6 - 1.0) / r2s, 0.0)
    return jnp.sum(d * fmag[..., None], axis=1)


# --------------------------------------------------------------------------
# AMR-Wind proxy (paper §5.3.3): one MLMG smoother level on the local box
# --------------------------------------------------------------------------


def amrwind_smooth(x_padded: jax.Array, rhs: jax.Array,
                   iters: int = 2) -> jax.Array:
    """Jacobi smoother on the 27-pt operator — the MLMG level work-horse."""
    return hpcg_symgs(x_padded, rhs, sweeps=iters)
