"""L2 model-graph correctness: LU pieces compose, CG operators behave."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model


def _dd_matrix(rng, n):
    """Diagonally dominant matrix (safe for unpivoted LU)."""
    a = rng.standard_normal((n, n))
    return jnp.asarray(a + n * np.eye(n), dtype=jnp.float64)


class TestHplPieces:
    def test_panel_factor_reconstructs(self):
        rng = np.random.default_rng(0)
        a = _dd_matrix(rng, 64)
        lu = model.hpl_panel_factor(a)
        l = jnp.tril(lu, -1) + jnp.eye(64)
        u = jnp.triu(lu)
        np.testing.assert_allclose(l @ u, a, rtol=1e-11, atol=1e-11)

    def test_trsm_row_solves(self):
        rng = np.random.default_rng(1)
        a = _dd_matrix(rng, 32)
        lu = model.hpl_panel_factor(a)
        l = jnp.tril(lu, -1) + jnp.eye(32)
        b = jnp.asarray(rng.standard_normal((32, 48)))
        x = model.hpl_trsm_row(lu, b)
        np.testing.assert_allclose(l @ x, b, rtol=1e-11, atol=1e-11)

    def test_trsm_col_solves(self):
        rng = np.random.default_rng(2)
        a = _dd_matrix(rng, 32)
        lu = model.hpl_panel_factor(a)
        u = jnp.triu(lu)
        b = jnp.asarray(rng.standard_normal((48, 32)))
        x = model.hpl_trsm_col(lu, b)
        np.testing.assert_allclose(x @ u, b, rtol=1e-10, atol=1e-10)

    def test_blocked_lu_end_to_end(self):
        """2x2-blocked right-looking LU == full LU (the HPL recursion)."""
        rng = np.random.default_rng(3)
        n, nb = 128, 64
        a = _dd_matrix(rng, n)
        m = jnp.array(a)
        # step 0
        lu00 = model.hpl_panel_factor(m[:nb, :nb])
        u01 = model.hpl_trsm_row(lu00, m[:nb, nb:])
        l10 = model.hpl_trsm_col(lu00, m[nb:, :nb])
        c = model.hpl_update(l10, u01, m[nb:, nb:])
        lu11 = model.hpl_panel_factor(c)
        # reassemble and verify LU = A
        lfull = jnp.zeros((n, n), jnp.float64)
        lfull = lfull.at[:nb, :nb].set(jnp.tril(lu00, -1))
        lfull = lfull.at[nb:, :nb].set(l10)
        lfull = lfull.at[nb:, nb:].set(jnp.tril(lu11, -1))
        lfull = lfull + jnp.eye(n)
        ufull = jnp.zeros((n, n), jnp.float64)
        ufull = ufull.at[:nb, :nb].set(jnp.triu(lu00))
        ufull = ufull.at[:nb, nb:].set(u01)
        ufull = ufull.at[nb:, nb:].set(jnp.triu(lu11))
        np.testing.assert_allclose(lfull @ ufull, a, rtol=1e-10, atol=1e-9)

    def test_residual_small_for_exact_solve(self):
        rng = np.random.default_rng(4)
        a = _dd_matrix(rng, 64)
        xtrue = jnp.asarray(rng.standard_normal(64))
        b = a @ xtrue
        x = jnp.linalg.solve(a, b)
        r = model.hpl_residual(a, x, b)
        assert float(r) < 16.0  # HPL pass threshold

    def test_residual_large_for_garbage(self):
        rng = np.random.default_rng(5)
        a = _dd_matrix(rng, 64)
        b = jnp.asarray(rng.standard_normal(64))
        r = model.hpl_residual(a, jnp.zeros(64, jnp.float64) + 100.0, b)
        assert float(r) > 16.0


class TestMxp:
    def test_ir_reduces_residual(self):
        """FP64 IR over a bf16-quality solve converges (MxP core claim)."""
        rng = np.random.default_rng(6)
        n = 128
        a = _dd_matrix(rng, n)
        xtrue = jnp.asarray(rng.standard_normal(n))
        b = a @ xtrue
        # low-precision "factorization": solve in f32 (proxy for bf16 LU)
        a32 = a.astype(jnp.float32)
        x = jnp.linalg.solve(a32, b.astype(jnp.float32)).astype(jnp.float64)
        _, r0 = model.mxp_ir_step(a, x, b)
        for _ in range(3):
            r, _ = model.mxp_ir_step(a, x, b)
            dx = jnp.linalg.solve(a32, r.astype(jnp.float32))
            x = x + dx.astype(jnp.float64)
        _, r1 = model.mxp_ir_step(a, x, b)
        assert float(r1) < 1e-8 * float(r0)

    def test_mxp_update_matches_f64_coarsely(self):
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        got = model.mxp_update(a, b, c)
        want = c - a @ b
        np.testing.assert_allclose(got, want, rtol=0.2, atol=0.5)  # bf16


class TestHpcg:
    def test_spmv_positive_definite_direction(self):
        """<x, Ax> > 0 for x != 0 (operator is SPD on zero-padded domain)."""
        rng = np.random.default_rng(8)
        x = rng.standard_normal((8, 8, 8)).astype(np.float32)
        ax = model.hpcg_spmv(jnp.pad(jnp.asarray(x), 1))
        assert float(np.sum(np.asarray(ax) * x)) > 0

    def test_symgs_reduces_residual(self):
        rng = np.random.default_rng(9)
        n = 8
        b = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        x0 = jnp.zeros((n + 2,) * 3, jnp.float32)
        r0 = float(jnp.linalg.norm(b))
        x1 = model.hpcg_symgs(x0, b, sweeps=8)
        ax1 = model.hpcg_spmv(jnp.pad(x1, 1))
        r1 = float(jnp.linalg.norm(b - ax1))
        assert r1 < r0

    def test_dot_and_waxpby(self):
        a = jnp.ones((4, 4, 4), jnp.float32)
        assert float(model.hpcg_dot(a, a)) == pytest.approx(64.0)
        w = model.hpcg_waxpby(2.0, a, 3.0, a)
        np.testing.assert_allclose(w, 5.0)


class TestHacc:
    def test_fft_poisson_inverse_relation(self):
        """-k^2 phi_k = rho_k  =>  applying forward Laplacian-in-k recovers rho
        (up to the zero mode we null out)."""
        rng = np.random.default_rng(10)
        n = 16
        rho = rng.standard_normal((n, n, n)).astype(np.float32)
        rho -= rho.mean()  # remove zero mode
        phi = model.hacc_fft_poisson(jnp.asarray(rho))
        k = np.fft.fftfreq(n) * 2 * np.pi
        kz, ky, kx = np.meshgrid(k, k, k, indexing="ij")
        k2 = kz**2 + ky**2 + kx**2
        rho_back = np.real(np.fft.ifftn(np.fft.fftn(np.asarray(phi)) * -k2))
        np.testing.assert_allclose(rho_back, rho, rtol=1e-3, atol=1e-3)

    def test_short_range_antisymmetry(self):
        """Newton's third law: total force is ~0."""
        rng = np.random.default_rng(11)
        pos = jnp.asarray(rng.standard_normal((64, 3)), jnp.float32)
        f = model.hacc_short_range(pos)
        np.testing.assert_allclose(np.asarray(f).sum(axis=0), 0.0, atol=1e-3)


class TestNekbone:
    def test_ax_symmetric(self):
        """Stiffness operator is symmetric: <Au, v> == <u, Av>."""
        rng = np.random.default_rng(12)
        e, n = 4, 5
        d = jnp.asarray(rng.standard_normal((n, n)), jnp.float64)
        u = jnp.asarray(rng.standard_normal((e, n, n, n)), jnp.float64)
        v = jnp.asarray(rng.standard_normal((e, n, n, n)), jnp.float64)
        au, av = model.nekbone_ax(u, d), model.nekbone_ax(v, d)
        np.testing.assert_allclose(float(jnp.vdot(au, v)),
                                   float(jnp.vdot(u, av)), rtol=1e-10)

    def test_ax_positive_semidefinite(self):
        rng = np.random.default_rng(13)
        e, n = 2, 6
        d = jnp.asarray(rng.standard_normal((n, n)), jnp.float64)
        u = jnp.asarray(rng.standard_normal((e, n, n, n)), jnp.float64)
        assert float(jnp.vdot(model.nekbone_ax(u, d), u)) >= -1e-9

    def test_cg_local_updates(self):
        u = jnp.zeros(4)
        r = jnp.ones(4)
        p = jnp.ones(4)
        ax = jnp.full(4, 2.0)
        u2, r2, p2 = model.nekbone_cg_local(u, r, p, ax, 0.5, 0.25)
        np.testing.assert_allclose(u2, 0.5)
        np.testing.assert_allclose(r2, 0.0)
        np.testing.assert_allclose(p2, 0.25)  # p = r_new + beta * p_old


class TestLammps:
    def test_pair_force_antisymmetry(self):
        rng = np.random.default_rng(14)
        # jittered grid: bounded pair distances keep LJ forces finite
        grid = np.stack(np.meshgrid(*[np.arange(4.0)] * 3,
                                    indexing="ij"), -1).reshape(-1, 3)
        pos = jnp.asarray(grid + rng.uniform(-0.1, 0.1, grid.shape),
                          jnp.float32)
        f = model.lammps_pair_tile(pos, cutoff2=1.5)
        scale = float(np.abs(np.asarray(f)).max()) + 1e-6
        np.testing.assert_allclose(np.asarray(f).sum(axis=0) / scale, 0.0,
                                   atol=1e-4)

    def test_out_of_cutoff_no_force(self):
        pos = jnp.asarray([[0.0, 0, 0], [10.0, 0, 0]], jnp.float32)
        f = model.lammps_pair_tile(pos, cutoff2=1.0)
        np.testing.assert_allclose(f, 0.0)
