"""Kernel-vs-oracle correctness: the CORE signal for the L1 layer.

hypothesis sweeps shapes/dtypes; every Pallas kernel must match its pure-jnp
ref to tight tolerances (identical rounding for mxp_gemm, f64 ulps for HPL).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # offline environments may not ship hypothesis — degrade, don't skip
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

jax.config.update("jax_enable_x64", True)

from compile.kernels import hpl_trailing_update, mxp_gemm, stencil27
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=97)
SMALL = st.integers(min_value=3, max_value=20)


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------- mxp_gemm

class TestMxpGemm:
    def test_square(self):
        rng = np.random.default_rng(0)
        x, y = _rand(rng, 64, 64), _rand(rng, 64, 64)
        np.testing.assert_allclose(mxp_gemm(x, y), ref.mxp_gemm_ref(x, y),
                                   rtol=1e-6)

    def test_tile_aligned_256(self):
        rng = np.random.default_rng(1)
        x, y = _rand(rng, 256, 128), _rand(rng, 128, 256)
        np.testing.assert_allclose(mxp_gemm(x, y), ref.mxp_gemm_ref(x, y),
                                   rtol=1e-6)

    def test_returns_f32(self):
        x = jnp.ones((8, 8), jnp.float32)
        assert mxp_gemm(x, x).dtype == jnp.float32

    def test_identity(self):
        eye = jnp.eye(32, dtype=jnp.float32)
        a = jnp.arange(32.0 * 32).reshape(32, 32) / 64.0
        got = mxp_gemm(a, eye)
        np.testing.assert_allclose(
            got, a.astype(jnp.bfloat16).astype(jnp.float32), rtol=1e-6)

    def test_bad_shapes_raise(self):
        with pytest.raises(ValueError):
            mxp_gemm(jnp.ones((4, 5)), jnp.ones((4, 5)))

    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_hypothesis_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = _rand(rng, m, k), _rand(rng, k, n)
        got, want = mxp_gemm(x, y), ref.mxp_gemm_ref(x, y)
        # identical bf16 rounding => near-exact agreement
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS)
    def test_f64_inputs_accepted(self, m, k, n):
        rng = np.random.default_rng(7)
        x = _rand(rng, m, k, dtype=np.float64)
        y = _rand(rng, k, n, dtype=np.float64)
        np.testing.assert_allclose(mxp_gemm(x, y), ref.mxp_gemm_ref(x, y),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- trailing update

class TestHplUpdate:
    def test_square(self):
        rng = np.random.default_rng(2)
        a = _rand(rng, 96, 32, dtype=np.float64)
        b = _rand(rng, 32, 96, dtype=np.float64)
        c = _rand(rng, 96, 96, dtype=np.float64)
        np.testing.assert_allclose(hpl_trailing_update(a, b, c),
                                   ref.hpl_trailing_update_ref(a, b, c),
                                   rtol=1e-13)

    def test_zero_a_is_identity(self):
        rng = np.random.default_rng(3)
        c = _rand(rng, 40, 40, dtype=np.float64)
        a = jnp.zeros((40, 16), jnp.float64)
        b = _rand(rng, 16, 40, dtype=np.float64)
        np.testing.assert_allclose(hpl_trailing_update(a, b, c), c, rtol=0)

    def test_bad_shapes_raise(self):
        one = jnp.ones((4, 4))
        with pytest.raises(ValueError):
            hpl_trailing_update(one, one, jnp.ones((5, 4)))

    @settings(max_examples=20, deadline=None)
    @given(m=DIMS, k=st.integers(1, 48), n=DIMS, seed=st.integers(0, 2**31))
    def test_hypothesis_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = _rand(rng, m, k, dtype=np.float64)
        b = _rand(rng, k, n, dtype=np.float64)
        c = _rand(rng, m, n, dtype=np.float64)
        np.testing.assert_allclose(hpl_trailing_update(a, b, c),
                                   ref.hpl_trailing_update_ref(a, b, c),
                                   rtol=1e-12, atol=1e-12)


# ----------------------------------------------------------------- stencil

class TestStencil27:
    def test_constant_field_interior(self):
        """constant x: interior rows see 26*x - 26*x = 0."""
        xp = jnp.ones((8, 8, 8), jnp.float32)
        out = stencil27(xp)
        np.testing.assert_allclose(out[1:-1, 1:-1, 1:-1], 0.0, atol=1e-5)

    def test_matches_ref(self):
        rng = np.random.default_rng(4)
        xp = _rand(rng, 10, 9, 11)
        np.testing.assert_allclose(stencil27(xp), ref.stencil27_ref(xp),
                                   rtol=1e-5, atol=1e-5)

    def test_too_small_raises(self):
        with pytest.raises(ValueError):
            stencil27(jnp.ones((2, 5, 5)))

    def test_symmetry(self):
        """operator is symmetric: <Ax, y> == <x, Ay> on zero-padded blocks."""
        rng = np.random.default_rng(5)
        n = 6
        x = rng.standard_normal((n, n, n)).astype(np.float32)
        y = rng.standard_normal((n, n, n)).astype(np.float32)
        pad = lambda v: jnp.pad(jnp.asarray(v), 1)
        ax = stencil27(pad(x))
        ay = stencil27(pad(y))
        np.testing.assert_allclose(np.sum(np.asarray(ax) * y),
                                   np.sum(np.asarray(ay) * x), rtol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(nz=SMALL, ny=SMALL, nx=SMALL, seed=st.integers(0, 2**31))
    def test_hypothesis_shapes(self, nz, ny, nx, seed):
        rng = np.random.default_rng(seed)
        xp = _rand(rng, nz, ny, nx)
        np.testing.assert_allclose(stencil27(xp), ref.stencil27_ref(xp),
                                   rtol=1e-4, atol=1e-4)

    def test_f64(self):
        rng = np.random.default_rng(6)
        xp = _rand(rng, 7, 7, 7, dtype=np.float64)
        np.testing.assert_allclose(stencil27(xp), ref.stencil27_ref(xp),
                                   rtol=1e-12)
