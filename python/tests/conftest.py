"""Test bootstrap: make `compile.*` importable regardless of invocation dir.

The suite is run both as `pytest python/tests` from the repo root (CI, the
tier-1 driver) and as `pytest tests` from python/. The kernels package
lives at python/compile, which is only importable in the second case, so
pin the python/ directory onto sys.path here.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
