"""Deterministic stand-in for `hypothesis` when it is not installed.

The build environment is offline, so `hypothesis` may be missing. This
module implements the tiny subset the kernel tests use — `given`,
`settings` and `strategies.integers` — by enumerating a fixed, seeded
sample of each strategy instead of searching. Coverage is weaker than real
hypothesis (no shrinking, no adaptive generation) but the tests stay
meaningful: every run executes the same ~20 pseudo-random shape
combinations per property.

The wrapper deliberately exposes a parameterless signature (bar `self`):
pytest inspects test signatures for fixtures, and the strategy-drawn
arguments must not look like fixture requests.

When hypothesis IS available the tests import it directly and this module
is unused.
"""

import inspect
import random
import zlib


class _IntRange:
    def __init__(self, lo, hi):
        self.lo = lo
        self.hi = hi

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _IntRange(min_value, max_value)


# keep the `from ... import strategies as st` idiom working
st = strategies


def settings(max_examples=20, deadline=None, **_ignored):
    """Decorator factory: records max_examples for a later @given."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    """Run the wrapped test over a deterministic sample of the strategies."""

    def deco(fn):
        def run_cases(call):
            # @settings may sit above @given (setting the attribute on
            # `runner`) or below it (setting it on `fn`) — honor both
            n = getattr(
                runner,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", 20),
            )
            # crc32, not hash(): str hashing is salted per process, and
            # the cases must be identical on every run
            rng = random.Random(0xA0A0 ^ zlib.crc32(fn.__name__.encode()))
            for case in range(n):
                drawn = {
                    name: strat.draw(rng)
                    for name, strat in sorted(strats.items())
                }
                try:
                    call(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"fallback-hypothesis case {case} {drawn}: {e}"
                    ) from e

        params = list(inspect.signature(fn).parameters)
        if params and params[0] == "self":

            def runner(self):
                run_cases(lambda **kw: fn(self, **kw))

        else:

            def runner():
                run_cases(fn)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
