//! FMM one-sided communication study (paper §5.3.5, Tables 4-6).
//!
//! Reproduces the paper's one-sided experiments: MPI_Get vs MPI_Put,
//! HMEM on/off, the fence-or-overflow behaviour, and the 9x16
//! sub-communicator cliff — all at reduced message counts, extrapolated
//! back to the paper's totals.
//!
//! ```bash
//! cargo run --release --example fmm_onesided
//! ```

use aurorasim::apps::fmm;
use aurorasim::config::AuroraConfig;
use aurorasim::machine::Machine;
use aurorasim::mpi::rma::{RmaKind, RmaOp, WindowSim};
use aurorasim::mpi::{Comm, World};

fn main() -> anyhow::Result<()> {
    let machine = Machine::new(&AuroraConfig::small(4, 8));
    let scale = 0.02; // 2% of the paper's message counts, extrapolated

    println!("Table 4 — configurations");
    for (label, nodes, ranks, subs, msgs) in fmm::TABLE4 {
        println!("  {label:>7}: {nodes} node(s), {ranks} ranks, {subs} \
                  sub-comm(s), {msgs} messages");
    }

    for (kind, name, paper_with, paper_without) in [
        (RmaKind::Get, "Table 5 — MPI_Get",
         "0.9 / 1.1 / 1.6 / 14.5 s", "24.6 / 17.1 / 13.0 s"),
        (RmaKind::Put, "Table 6 — MPI_Put",
         "14.2 / 17.6 / 20.7 s", "28.4 / 38.9 / 49.7 s"),
    ] {
        println!("\n{name}  (paper: with HMEM {paper_with}; without \
                  {paper_without})");
        let with = fmm::table(&machine, kind, true, scale)?;
        let without = fmm::table(&machine, kind, false, scale)?;
        for (i, row) in with.iter().enumerate() {
            let wo = without
                .get(i)
                .map(|r| format!("{:.1} s", r.time))
                .unwrap_or_else(|| "NA".into());
            println!("  {:>7}: with HMEM {:.1} s   without {wo}",
                     row.label, row.time);
        }
    }

    println!("\nfence-or-overflow (paper: Put w/o HMEM needs a fence \
              every 100 calls):");
    let mut w = World::new(&machine.topo, machine.place_job(0, 1, 4));
    let comm = Comm::world(4);
    let mut win = WindowSim::new(4, 64, false);
    let burst: Vec<RmaOp> = (0..150)
        .map(|_| RmaOp { kind: RmaKind::Put, origin: 0, target: 1,
                         offset: 0, len: 8 })
        .collect();
    match win.run_phase(&mut w, &comm, &burst) {
        Err(e) => println!("  150 un-fenced Puts: {e}"),
        Ok(_) => println!("  unexpected success"),
    }

    println!("\ndata integrity over a ring of Gets: {}",
             if fmm::functional(&machine)? { "PASS" } else { "FAIL" });
    Ok(())
}
