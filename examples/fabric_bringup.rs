//! Fabric bring-up and operations tour (paper §3-§4): topology
//! addressing, adaptive routing under load, congestion management on/off,
//! QoS allocation, fabric-manager sweeps + orchestrated maintenance, and
//! the MPI microbenchmarks of §5.1.
//!
//! ```bash
//! cargo run --release --example fabric_bringup
//! ```

use aurorasim::apps::osu;
use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{DesOpts, DesSim};
use aurorasim::fabric::qos::QosProfile;
use aurorasim::fabric::{Flow, Router, RoutedFlow, TrafficClass};
use aurorasim::fabricmgr::FabricManager;
use aurorasim::machine::Machine;
use aurorasim::topology::LinkId;

fn main() -> anyhow::Result<()> {
    let machine = Machine::new(&AuroraConfig::small(8, 4));
    let topo = &machine.topo;

    println!("=== algorithmic addressing (§3.6/§3.7) ===");
    for nic in [0u32, 77, 511] {
        let addr = topo.fabric_addr(nic);
        println!("  nic {nic}: group {} switch {} port {} (static ARP \
                  resolves back to {})",
                 addr.group, addr.switch, addr.port, topo.resolve(addr));
    }

    println!("\n=== adaptive routing under a hot group pair (§3.1) ===");
    let mut router = Router::new(topo);
    for i in 0..600 {
        let f = Flow::new((i % 16) as u32, 300 + (i % 16) as u32, 1 << 20);
        router.route(&f);
    }
    println!("  routed {} flows, {} diverted non-minimally (Valiant)",
             router.total_routed, router.nonminimal_count);

    println!("\n=== congestion management on/off (§3.1, Fig 5) ===");
    let mut r2 = Router::new(topo);
    let mut flows: Vec<RoutedFlow> = (0..10)
        .map(|i| {
            let f = Flow::new(i * 8, 200, 8 << 20); // 10-way incast
            RoutedFlow { path: r2.route(&f), flow: f }
        })
        .collect();
    let victim = Flow::new(1, 280, 1 << 20);
    flows.push(RoutedFlow { path: r2.route(&victim), flow: victim });
    for mgmt in [true, false] {
        let sim = DesSim::new(topo,
            DesOpts { congestion_mgmt: mgmt, ..DesOpts::default() });
        let res = sim.run_simultaneous(&flows);
        println!("  congestion mgmt {}: victim flow time {:.2} ms",
                 if mgmt { "ON " } else { "OFF" },
                 res.per_flow[10] * 1e3);
    }

    println!("\n=== QoS profile LlBeBdEt (§4.2.3) ===");
    let q = QosProfile::llbebdet();
    let shares = q.allocate(&[
        (TrafficClass::LowLatency, 0.5),
        (TrafficClass::BulkData, 2.0),
        (TrafficClass::BestEffort, 2.0),
        (TrafficClass::Ethernet, 1.0),
    ]);
    println!("  contended link shares: LL {:.2} Bd {:.2} Be {:.2} Et {:.2}",
             shares[0], shares[1], shares[2], shares[3]);

    println!("\n=== fabric manager (§3.5, §4.1-4.2) ===");
    let mut fm = FabricManager::new(&machine.cfg);
    let link = LinkId::Local { group: 2, a: 0, b: 3 };
    fm.set_degraded(link, 2);
    println!("  degraded link {link:?}: bw x{}", fm.bw_multiplier(&link));
    fm.enter_maintenance(link);
    println!("  orchestrated maintenance: bw x{}", fm.bw_multiplier(&link));
    fm.restore(link);
    println!("  restored: bw x{}", fm.bw_multiplier(&link));
    fm.failover();
    println!("  active-standby failover: active = {}", fm.active);

    println!("\n=== §5.1 microbenchmarks ===");
    println!("  Fig 10 p2p latency:");
    for (b, l) in osu::p2p_latency_sweep(&machine, &[8, 64, 128, 4096]) {
        println!("    {b:>6} B: {:.2} us", l * 1e6);
    }
    println!("  Fig 11/13 socket bandwidth (8 ranks):");
    println!("    host: {:.1} GB/s   gpu: {:.1} GB/s",
             osu::socket_bandwidth(&machine, 8, false) / 1e9,
             osu::socket_bandwidth(&machine, 8, true) / 1e9);
    Ok(())
}
