//! Quickstart: build the Aurora machine model, launch an MPI job through
//! the coordinator, and read the reports.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use aurorasim::config::AuroraConfig;
use aurorasim::coordinator::{JobSpec, Launcher};
use aurorasim::machine::Machine;
use aurorasim::mpi::{coll, Comm};

fn main() -> anyhow::Result<()> {
    // The full 10,624-node Aurora (topology is algorithmic: O(1) memory).
    let aurora = Machine::aurora();
    println!("{}\n", aurora.spec_table());

    // A small dragonfly with identical per-link constants for job runs.
    let machine = Machine::new(&AuroraConfig::small(8, 4)); // 64 nodes
    let mut launcher = Launcher::new(&machine);

    // Launch: 16 nodes x 8 ranks, balanced NUMA/NIC binding (§3.8.4).
    let spec = JobSpec::new("quickstart-allreduce", 16, 8);
    let report = launcher.launch(&spec, |world| {
        let comm = Comm::world(16 * 8);
        let mut out = Vec::new();
        for bytes in [8u64, 1 << 10, 64 << 10, 1 << 20] {
            out.push((bytes, coll::allreduce(world, &comm, bytes)));
        }
        out
    })?;

    println!("MPI_Allreduce on {} ranks:", spec.ranks());
    for (bytes, t) in &report.result {
        println!("  {:>8} B  {:>10.1} us", bytes, t * 1e6);
    }
    println!("\ncpu-bind (first 4 ranks): {:?}",
             &report.cpu_binds[..4.min(report.cpu_binds.len())]);
    println!("{}", report.mpich_summary);
    println!("{}", report.counter_report);
    Ok(())
}
