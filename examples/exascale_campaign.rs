//! End-to-end driver: the full Aurora bring-up -> validation -> benchmark
//! campaign of the paper, exercising every layer of the stack on a real
//! small workload:
//!
//! 1. fabric-manager bring-up (routing tables, sweeps, a link flap);
//! 2. the §3.8 validation ladder with injected node faults, repair loop;
//! 3. the all2all + GPCNet pre-flight gates;
//! 4. **functional HPL** — a distributed blocked LU where every tile op
//!    executes the AOT Pallas/JAX artifacts through PJRT (L1+L2) over the
//!    simulated fabric (L3), accepted by the HPL scaled-residual check;
//! 5. functional HPL-MxP IR, HPCG CG, Nekbone CG, Graph500 BFS;
//! 6. at-scale performance reproduction of the paper's headline numbers.
//!
//! ```bash
//! make artifacts && cargo run --release --example exascale_campaign
//! ```

use aurorasim::apps;
use aurorasim::config::AuroraConfig;
use aurorasim::fabricmgr::FabricManager;
use aurorasim::machine::Machine;
use aurorasim::metrics::{fmt_flops, fmt_time};
use aurorasim::reproduce;
use aurorasim::runtime::Runtime;
use aurorasim::topology::LinkId;
use aurorasim::validate::{NodeFault, Validator};

fn main() -> anyhow::Result<()> {
    println!("=== 1. fabric bring-up ===");
    let aurora_cfg = AuroraConfig::aurora();
    let mut fm = FabricManager::new(&aurora_cfg);
    let machine = Machine::new(&AuroraConfig::small(8, 4)); // 64-node testbed
    println!("fabric manager controls {} switches", fm.switch_count());
    println!(
        "routing table entries: {}",
        fm.routing_table_entries(&Machine::aurora().topo)
    );
    let flappy = LinkId::Global { src: 1, dst: 5, idx: 0 };
    fm.record_flap(flappy, 60.0, 3);
    println!("link {flappy:?} flapped -> drained: bw x{}",
             fm.bw_multiplier(&flappy));
    fm.retune_complete(flappy);
    println!("retuned -> bw x{}", fm.bw_multiplier(&flappy));
    for fired in [fm.tick(5.0), fm.tick(5.0)] {
        println!("sweeps fired: {fired:?}");
    }

    println!("\n=== 2. validation ladder (§3.8) ===");
    let mut v = Validator::new(&machine);
    v.inject(5, NodeFault { perf_factor: 0.4, ..Default::default() });
    v.inject(11, NodeFault { hw_errors: 2, ..Default::default() });
    let all: Vec<usize> = (0..machine.cfg.nodes()).collect();
    for rep in v.systematic(&all) {
        println!(
            "  {:?}: tested {:3}  failed {:?}",
            rep.level, rep.tested_nodes, rep.failed_nodes
        );
    }
    let repaired = v.repair_and_revalidate();
    println!("  repaired + revalidated: {repaired:?}");

    println!("\n=== 3. pre-flight gates ===");
    let bw = apps::alltoall::small_scale_check(&machine, 16, 4, 64 << 10);
    println!("  all2all (16 nodes x 4): aggregate {:.1} GB/s", bw / 1e9);
    let gp = apps::gpcnet::Gpcnet::default().run(&machine, true);
    println!(
        "  GPCNet: isolated RR lat {:.1} us, congested {:.1} us \
         (CIF {:.1}x)",
        gp.rr_lat_isolated.0 * 1e6,
        gp.rr_lat_congested.0 * 1e6,
        gp.cif_lat.0
    );

    println!("\n=== 4-5. functional benchmarks (PJRT artifacts) ===");
    let mut rt = Runtime::open("artifacts")?;
    println!("  PJRT platform: {}", rt.platform());
    print!("{}", reproduce::functional_suite(&mut rt)?);
    let counts = rt.call_counts();
    let total_calls: u64 = counts.values().sum();
    println!("  artifact executions: {total_calls} across {} kernels",
             counts.len());

    println!("\n=== 6. at-scale reproduction (headline numbers) ===");
    let hpl = apps::hpl::performance(&aurora_cfg, 9234);
    println!(
        "  HPL     : {} on 9,234 nodes ({:.2}% eff, {})  [paper: 1.012 \
         EF/s, 78.84%, 4h21m54s]",
        fmt_flops(hpl.rate),
        hpl.efficiency * 100.0,
        fmt_time(hpl.time)
    );
    let mxp = apps::hpl_mxp::performance(&aurora_cfg, 9500);
    println!(
        "  HPL-MxP : {} on 9,500 nodes  [paper: 11.64 EF/s]",
        fmt_flops(mxp.rate)
    );
    let g = apps::graph500::performance(&aurora_cfg, 8192, 42);
    println!(
        "  Graph500: {:.0} GTEPS at scale 42 on 8,192 nodes  [paper: \
         69,373]",
        g.gteps
    );
    let h = apps::hpcg::performance(&aurora_cfg, 4096);
    println!(
        "  HPCG    : {:.3} PF/s on 4,096 nodes  [paper: 5.613]",
        h.pflops
    );
    let a2a = apps::alltoall::Alltoall::paper().peak(&aurora_cfg);
    println!(
        "  all2all : {:.2} TB/s aggregate at 9,658 nodes  [paper: 228.92]",
        a2a / 1e12
    );
    println!("\ncampaign complete.");
    Ok(())
}
