#!/usr/bin/env python3
"""Bench regression gate (CI).

Compares a fresh BENCH_fabric.json (written by
`BENCH_JSON=BENCH_fabric.json cargo bench --bench fabric`) against the
committed baseline `ci/bench_baseline.json`:

* metrics: fail if current us_per_iter exceeds baseline by more than the
  threshold (default +25%). Baseline values of null are *unpinned*
  (bootstrap state): they warn and print the measured value so a
  maintainer can pin them (or run with --update on a reference machine).
  Improvements beyond the threshold pass but suggest re-pinning.
* ratio_floors: machine-independent ratios (e.g. incremental/oracle DES
  speedup) that must stay above their floor regardless of host speed.

Usage:
    python3 ci/check_bench.py BENCH_fabric.json [--threshold 0.25]
                              [--baseline ci/bench_baseline.json]
                              [--update]
Exit code 0 = pass, 1 = regression.
"""

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH_fabric.json")
    ap.add_argument("--baseline", default="ci/bench_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed relative slowdown (0.25 = +25%%)")
    ap.add_argument("--update", action="store_true",
                    help="write measured values into the baseline")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    if cur.get("schema") != "aurorasim.bench/v1":
        print(f"error: unexpected schema {cur.get('schema')!r}")
        return 1

    cur_metrics = {k: v["us_per_iter"] for k, v in cur["metrics"].items()}
    cur_ratios = cur.get("ratios", {})
    failures, notes = [], []

    for key, want in sorted(base.get("metrics", {}).items()):
        if key.startswith("_"):
            continue
        got = cur_metrics.get(key)
        if got is None:
            failures.append(f"{key}: in baseline but missing from run")
            continue
        if want is None:
            notes.append(f"{key}: unpinned baseline; measured "
                         f"{got:.3f} us/iter")
            continue
        rel = (got - want) / want
        if rel > args.threshold:
            failures.append(
                f"{key}: {got:.3f} us/iter vs baseline {want:.3f} "
                f"(+{rel * 100:.0f}% > +{args.threshold * 100:.0f}%)")
        elif rel < -args.threshold:
            notes.append(
                f"{key}: improved {rel * 100:.0f}% "
                f"({want:.3f} -> {got:.3f} us/iter); consider re-pinning")

    for key, floor in sorted(base.get("ratio_floors", {}).items()):
        if key.startswith("_"):
            continue
        got = cur_ratios.get(key)
        if got is None:
            failures.append(f"{key}: ratio floor set but ratio missing")
        elif got < floor:
            failures.append(f"{key}: ratio {got:.2f} below floor {floor}")
        else:
            notes.append(f"{key}: ratio {got:.2f} (floor {floor}) ok")

    for key in sorted(set(cur_metrics) - set(base.get("metrics", {}))):
        notes.append(f"{key}: measured {cur_metrics[key]:.3f} us/iter "
                     f"but not in baseline (add it to track)")

    for n in notes:
        print(f"note: {n}")
    for f_ in failures:
        print(f"FAIL: {f_}")

    if args.update:
        base.setdefault("metrics", {})
        for key, val in cur_metrics.items():
            base["metrics"][key] = round(val, 3)
        with open(args.baseline, "w") as f:
            json.dump(base, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")

    if failures:
        print(f"{len(failures)} bench regression(s)")
        return 1
    print("bench gate: pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
