//! Cross-tier and property-based integration tests: the DES, round and
//! analytic tiers must agree where their domains overlap, and the fabric
//! invariants (capacity, latency floors, routing validity) must hold for
//! randomized workloads (in-tree property testing; the registry is
//! offline so proptest is replaced by seeded Pcg sweeps).

use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{DesOpts, DesSim};
use aurorasim::fabric::rounds::CostModel;
use aurorasim::fabric::{analytic, BufLoc, Flow, RoutedFlow, Router};
use aurorasim::machine::Machine;
use aurorasim::topology::{LinkId, Topology};
use aurorasim::util::Pcg;

fn random_flows(topo: &Topology, rng: &mut Pcg, n: usize, max_bytes: u64)
    -> Vec<RoutedFlow> {
    let mut router = Router::with_seed(topo, rng.next_u64());
    let nics = topo.cfg.compute_endpoints() as u64;
    (0..n)
        .map(|_| {
            let src = rng.gen_range(nics) as u32;
            let mut dst = rng.gen_range(nics) as u32;
            if dst == src {
                dst = (dst + 1) % nics as u32;
            }
            let f = Flow::new(src, dst, 1 + rng.gen_range(max_bytes));
            RoutedFlow { path: router.route(&f), flow: f }
        })
        .collect()
}

#[test]
fn property_des_never_beats_zero_load_latency() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let cm = CostModel::new(&topo);
    let mut rng = Pcg::new(1);
    for case in 0..20 {
        let flows = random_flows(&topo, &mut rng, 16, 1 << 22);
        let res = DesSim::new(&topo, DesOpts::default())
            .run_simultaneous(&flows);
        for (i, rf) in flows.iter().enumerate() {
            let floor = cm.msg_latency(&rf.path, rf.flow.bytes, BufLoc::Host)
                + rf.flow.bytes as f64 / topo.cfg.rank_issue_bw_host;
            assert!(
                res.per_flow[i] >= floor * 0.999,
                "case {case} flow {i}: {} < floor {}",
                res.per_flow[i],
                floor
            );
        }
    }
}

#[test]
fn property_round_tier_never_beats_des() {
    // the round tier is an upper-bound approximation of max-min sharing:
    // completion within [0.3x, 3x] of DES across random rounds
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let cm = CostModel::new(&topo);
    let mut rng = Pcg::new(2);
    for case in 0..12 {
        let flows = random_flows(&topo, &mut rng, 24, 1 << 24);
        let des = DesSim::new(&topo, DesOpts::default())
            .run_simultaneous(&flows);
        let rounds = cm.eval_round(&flows);
        let ratio = rounds.makespan / des.makespan;
        assert!(
            (0.3..=3.0).contains(&ratio),
            "case {case}: round {} vs DES {} (x{ratio:.2})",
            rounds.makespan,
            des.makespan
        );
    }
}

#[test]
fn property_incast_respects_ejection_capacity() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(3);
    for fanin in [4usize, 8, 16, 32] {
        let bytes = 8u64 << 20;
        let dst = 100u32;
        let mut router = Router::new(&topo);
        let flows: Vec<RoutedFlow> = (0..fanin)
            .map(|_| {
                let src = rng.gen_range(
                    topo.cfg.compute_endpoints() as u64) as u32;
                let src = if topo.node_of_nic(src) == topo.node_of_nic(dst) {
                    src + 16
                } else {
                    src
                };
                let f = Flow::new(src, dst, bytes);
                RoutedFlow { path: router.route(&f), flow: f }
            })
            .collect();
        let res = DesSim::new(&topo, DesOpts::default())
            .run_simultaneous(&flows);
        let agg = fanin as f64 * bytes as f64 / res.makespan;
        assert!(
            agg <= topo.cfg.nic_eff_bw_host * 1.10,
            "fanin {fanin}: aggregate {agg} exceeds ejection"
        );
    }
}

#[test]
fn property_paths_always_well_formed() {
    let topo = Topology::new(&AuroraConfig::small(8, 8));
    let mut rng = Pcg::new(4);
    let mut router = Router::new(&topo);
    let nics = topo.cfg.compute_endpoints() as u64;
    for _ in 0..500 {
        let src = rng.gen_range(nics) as u32;
        let mut dst = rng.gen_range(nics) as u32;
        if dst == src {
            dst = (dst + 1) % nics as u32;
        }
        let p = router.route(&Flow::new(src, dst, 1 << 16));
        assert_eq!(p.links.first(), Some(&LinkId::NicUp(src)));
        assert_eq!(p.links.last(), Some(&LinkId::NicDown(dst)));
        if p.minimal {
            assert!(p.switch_hops <= 3, "minimal > 3 hops");
        } else {
            assert!(p.switch_hops <= 5, "valiant > 5 hops");
        }
        // no repeated links (loop-free)
        let mut seen = std::collections::HashSet::new();
        for l in &p.links {
            assert!(seen.insert(*l), "loop at {l:?}");
        }
    }
}

#[test]
fn alltoall_tiers_converge_at_overlap_scale() {
    // the Fig 4 analytic tier vs the round tier at 8..16 nodes
    let m = Machine::new(&AuroraConfig::small(4, 4));
    for nodes in [8usize, 16] {
        let got = aurorasim::apps::alltoall::small_scale_check(
            &m, nodes, 2, 128 << 10);
        let predicted =
            analytic::alltoall_aggregate_bw(&m.cfg, nodes, 2, 128 << 10);
        let ratio = got / predicted;
        assert!(
            (0.25..4.0).contains(&ratio),
            "{nodes} nodes: rounds {got:.3e} analytic {predicted:.3e}"
        );
    }
}

#[test]
fn property_more_bytes_never_finish_faster() {
    let topo = Topology::new(&AuroraConfig::small(4, 4));
    let cm = CostModel::new(&topo);
    let mut rng = Pcg::new(5);
    for _ in 0..50 {
        let src = rng.gen_range(256) as u32;
        let dst = 256 + rng.gen_range(200) as u32;
        let p = topo.minimal_path(src, dst, 0);
        let b1 = 1 + rng.gen_range(1 << 20);
        let b2 = b1 + 1 + rng.gen_range(1 << 20);
        let t1 = cm.solo_msg_time(&p, b1, BufLoc::Host);
        let t2 = cm.solo_msg_time(&p, b2, BufLoc::Host);
        assert!(t2 >= t1, "{b1}B {t1}s vs {b2}B {t2}s");
    }
}

#[test]
fn property_degraded_links_monotone() {
    let topo = Topology::new(&AuroraConfig::small(4, 4));
    let mut rng = Pcg::new(6);
    let flows = random_flows(&topo, &mut rng, 8, 1 << 24);
    let base = DesSim::new(&topo, DesOpts::default())
        .run_simultaneous(&flows);
    for lanes in [3u8, 2, 1] {
        let mut degraded = std::collections::BTreeMap::new();
        for rf in &flows {
            for l in &rf.path.links {
                degraded.insert(*l, lanes as f64 / 4.0);
            }
        }
        let slow = DesSim::new(&topo, DesOpts { degraded, ..DesOpts::default() })
            .run_simultaneous(&flows);
        assert!(
            slow.makespan >= base.makespan * 0.999,
            "lanes {lanes}: {} < {}",
            slow.makespan,
            base.makespan
        );
    }
}
