//! PR-7 session-API satellite: every legacy `DesSim` entry point
//! (`run`, `run_with`, `run_dag`, `run_dag_with`,
//! `run_simultaneous_with`, `run_stream_with`, `run_stream_sink`) must
//! be **bit-identical** to its [`DesSession`] twin — the legacy names
//! are thin `#[doc(hidden)]` wrappers over the same implementations, so
//! these tests pin that the builder introduces no arithmetic, ordering
//! or scratch-handling difference whatsoever (f64s compared by bits).

use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{
    DagResult, DesOpts, DesResult, DesScratch, DesSim, StreamResult,
    TimedFlow,
};
use aurorasim::fabric::workload;
use aurorasim::fabric::{Flow, FlowTimes, RoutedFlow, Router};
use aurorasim::topology::Topology;
use aurorasim::util::Pcg;

fn topo() -> Topology {
    Topology::new(&AuroraConfig::small(4, 4))
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn routed_flows(t: &Topology, n: usize, seed: u64) -> Vec<RoutedFlow> {
    let mut rng = Pcg::new(seed);
    let mut router = Router::with_seed(t, seed);
    let nics = t.cfg.compute_endpoints() as u64;
    (0..n)
        .map(|i| {
            let src = rng.gen_range(nics) as u32;
            let dst =
                (src + 1 + rng.gen_range(nics - 1) as u32) % nics as u32;
            let f = Flow::new(src, dst, (1 + i as u64 % 7) << 18);
            RoutedFlow { path: router.route(&f), flow: f }
        })
        .collect()
}

fn timed_flows(t: &Topology, n: usize, seed: u64) -> Vec<TimedFlow> {
    routed_flows(t, n, seed)
        .into_iter()
        .enumerate()
        .map(|(i, rf)| TimedFlow { rf, start: (i % 5) as f64 * 2e-4 })
        .collect()
}

fn assert_des_eq(a: &DesResult, b: &DesResult) {
    assert_eq!(bits(&a.finish), bits(&b.finish));
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.contributors, b.contributors);
    assert_eq!(a.victims, b.victims);
    assert_eq!(a.solve_batches, b.solve_batches);
    assert_eq!(a.components_solved, b.components_solved);
    assert_eq!(a.fastpath_components, b.fastpath_components);
}

fn assert_dag_eq(a: &DagResult, b: &DagResult) {
    assert_eq!(bits(&a.node_finish), bits(&b.node_finish));
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.contributors, b.contributors);
    assert_eq!(a.victims, b.victims);
    assert_eq!(a.solve_batches, b.solve_batches);
    assert_eq!(a.components_solved, b.components_solved);
    assert_eq!(a.fastpath_components, b.fastpath_components);
}

fn assert_stream_eq(a: &StreamResult, b: &StreamResult) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.total_nodes, b.total_nodes);
    assert_eq!(a.peak_live_nodes, b.peak_live_nodes);
    assert_eq!(a.contributors, b.contributors);
    assert_eq!(a.victims, b.victims);
    assert_eq!(a.late_releases, b.late_releases);
    assert_eq!(a.solve_batches, b.solve_batches);
    assert_eq!(a.components_solved, b.components_solved);
    assert_eq!(a.fastpath_components, b.fastpath_components);
}

fn assert_times_eq(a: &FlowTimes, b: &FlowTimes) {
    assert_eq!(bits(&a.per_flow), bits(&b.per_flow));
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
}

#[test]
fn run_matches_session_solve() {
    let t = topo();
    let flows = timed_flows(&t, 96, 3);
    let sim = DesSim::new(&t, DesOpts::default());
    let legacy = sim.run(&flows);
    let session = sim.session(&mut DesScratch::default()).solve(&flows);
    assert_des_eq(&legacy, &session);
}

#[test]
fn run_with_matches_session_solve() {
    let t = topo();
    let flows = timed_flows(&t, 96, 5);
    let sim = DesSim::new(&t, DesOpts::default());
    let mut s1 = DesScratch::new();
    let mut s2 = DesScratch::new();
    let legacy = sim.run_with(&flows, &mut s1);
    let session = sim.session(&mut s2).solve(&flows);
    assert_des_eq(&legacy, &session);
    // and scratch reuse does not perturb the session path either
    let again = sim.session(&mut s2).solve(&flows);
    assert_des_eq(&legacy, &again);
}

#[test]
fn run_simultaneous_with_matches_session_simultaneous() {
    let t = topo();
    let flows = routed_flows(&t, 128, 7);
    let sim = DesSim::new(&t, DesOpts::default());
    let legacy = sim.run_simultaneous_with(&flows, &mut DesScratch::new());
    let session =
        sim.session(&mut DesScratch::new()).simultaneous(&flows);
    assert_times_eq(&legacy, &session);
}

#[test]
fn run_dag_and_run_dag_with_match_session_dag() {
    let t = topo();
    let nics = workload::spread_nics(&t, 24);
    let mut router = Router::with_seed(&t, 11);
    let rr = workload::ring_rounds(&nics, 8, 1 << 20);
    let dag = workload::dag_from_rounds(&mut router, &rr, 0.0);
    let sim = DesSim::new(&t, DesOpts::default());
    let legacy = sim.run_dag(&dag);
    let legacy_with = sim.run_dag_with(&dag, &mut DesScratch::new());
    let session = sim.session(&mut DesScratch::new()).dag(&dag);
    assert_dag_eq(&legacy, &session);
    assert_dag_eq(&legacy_with, &session);
}

fn ring_stream_result(
    t: &Topology,
    sim: &DesSim,
    via_session: bool,
) -> StreamResult {
    let nics = workload::spread_nics(t, 24);
    let rr = workload::ring_rounds(&nics, 8, 1 << 20);
    let mut router = Router::with_seed(t, 13);
    let mut src = workload::routed_round_source(&mut router, move |k| {
        rr.get(k).cloned()
    });
    if via_session {
        sim.session(&mut DesScratch::new()).stream(&mut src)
    } else {
        sim.run_stream_with(&mut src, &mut DesScratch::new())
    }
}

#[test]
fn run_stream_with_matches_session_stream() {
    let t = topo();
    let sim = DesSim::new(&t, DesOpts::default());
    let legacy = ring_stream_result(&t, &sim, false);
    let session = ring_stream_result(&t, &sim, true);
    assert!(legacy.total_nodes > 0);
    assert_stream_eq(&legacy, &session);
}

#[test]
fn run_stream_sink_matches_session_stream_sink() {
    let t = topo();
    let sim = DesSim::new(&t, DesOpts::default());
    let run = |via_session: bool| {
        let nics = workload::spread_nics(&t, 24);
        let rr = workload::ring_rounds(&nics, 8, 1 << 20);
        let mut router = Router::with_seed(&t, 17);
        let mut src =
            workload::routed_round_source(&mut router, move |k| {
                rr.get(k).cloned()
            });
        let mut sunk: Vec<(u32, u64)> = Vec::new();
        let res = if via_session {
            sim.session(&mut DesScratch::new())
                .stream_sink(&mut src, |id, t| sunk.push((id, t.to_bits())))
        } else {
            sim.run_stream_sink(&mut src, &mut DesScratch::new(), |id, t| {
                sunk.push((id, t.to_bits()))
            })
        };
        (res, sunk)
    };
    let (legacy, sunk_l) = run(false);
    let (session, sunk_s) = run(true);
    assert_eq!(sunk_l.len(), legacy.total_nodes);
    assert_eq!(sunk_l, sunk_s, "sink callbacks must replay identically");
    assert_stream_eq(&legacy, &session);
}

#[test]
fn session_opts_override_matches_dedicated_sim() {
    let t = topo();
    let flows = timed_flows(&t, 96, 19);
    let nocm = DesOpts { congestion_mgmt: false, ..DesOpts::default() };
    let dedicated = DesSim::new(&t, nocm.clone()).run(&flows);
    // base sim has CM on; the session override must fully replace it
    let base = DesSim::new(&t, DesOpts::default());
    let overridden = base
        .session(&mut DesScratch::new())
        .opts(nocm)
        .solve(&flows);
    assert_des_eq(&dedicated, &overridden);
    // and a session WITHOUT the override must match the base sim, not
    // the overridden one (the override is per-session, not sticky)
    let plain = base.session(&mut DesScratch::new()).solve(&flows);
    assert_des_eq(&base.run(&flows), &plain);
}
