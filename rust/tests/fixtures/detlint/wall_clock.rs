// detlint fixture: R2 wall-clock must fire (never compiled).
use std::time::Instant;

pub fn solve_timed() -> f64 {
    let t0 = Instant::now();
    let since_epoch = std::time::SystemTime::now();
    let _ = since_epoch;
    t0.elapsed().as_secs_f64()
}
