// detlint fixture: R1 std-hash-container must fire (scanned as if at
// fabric/<this file> by tests/detlint.rs; never compiled).
use std::collections::HashMap;

pub fn link_loads() -> HashMap<u32, f64> {
    let mut m = std::collections::HashMap::new();
    m.insert(0u32, 1.0f64);
    m
}
