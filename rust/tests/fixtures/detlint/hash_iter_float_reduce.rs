// detlint fixture: R4 hash-iter-float-reduce must fire (never compiled).
use rustc_hash::FxHashMap;

pub fn total_rate(rates: &FxHashMap<u32, f64>) -> f64 {
    let direct: f64 = rates.values().sum();
    let folded = rates.values().fold(0.0, |a, b| a + b);
    let keyed: f64 = rates.keys().map(|&k| k as f64).sum();
    direct + folded + keyed
}
