// detlint fixture: R5 f32-rate must fire (never compiled).
pub fn share(bytes: u64, dt: f64) -> f32 {
    let rate = bytes as f32 / dt as f32;
    let cap: f32 = 25.0e9;
    rate.min(cap)
}
