// detlint fixture: R3 thread-spawn must fire outside campaign/pool.rs
// (never compiled).
pub fn fan_out(n: usize) {
    let handles: Vec<_> =
        (0..n).map(|i| std::thread::spawn(move || i * 2)).collect();
    for h in handles {
        h.join().unwrap();
    }
}
