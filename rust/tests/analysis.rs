//! PR-8 workload verifier: corrupt DAGs are rejected with structured
//! diagnostics *before* any solve (both through `WorkloadAnalyzer`
//! directly and through the debug-build executor hooks), the
//! `mpi::coll` round generators satisfy their closed-form
//! byte-conservation identities, and every campaign scenario lints
//! clean end to end.

use std::panic::AssertUnwindSafe;

use aurorasim::campaign::{Campaign, Scenario, Workload};
use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{DesOpts, DesScratch, DesSim};
use aurorasim::fabric::{
    check_collective_rounds, workload, Collective, Flow, RoundSource, Router,
    RoutedFlow, RpcClass, Severity, StreamNode, WorkloadAnalyzer,
};
use aurorasim::fabric::workload::{DagKind, DagNode, DagWorkload, NO_KEY};
use aurorasim::mpi::{coll, Comm};
use aurorasim::topology::Topology;

fn topo() -> Topology {
    Topology::new(&AuroraConfig::small(4, 4))
}

fn routed(r: &mut Router, s: u32, d: u32, bytes: u64) -> RoutedFlow {
    let f = Flow::new(s, d, bytes);
    RoutedFlow { path: r.route(&f), flow: f }
}

/// A two-node dependency cycle, built by bypassing `DagWorkload::push`
/// (whose `deps < id` assert already stops forward deps) straight into
/// the `pub nodes` escape hatch.
fn cyclic_dag(t: &Topology) -> DagWorkload {
    let mut r = Router::new(t);
    let nics = workload::spread_nics(t, 4);
    let mut wl = DagWorkload::new();
    wl.nodes.push(DagNode {
        kind: DagKind::Xfer(routed(&mut r, nics[0], nics[1], 1 << 20)),
        deps: vec![1],
        start: 0.0,
    });
    wl.nodes.push(DagNode {
        kind: DagKind::Xfer(routed(&mut r, nics[2], nics[3], 1 << 20)),
        deps: vec![0],
        start: 0.0,
    });
    wl
}

/// The analyzer names the cycle with a structured diagnostic: an
/// `Error`-severity `cycle` check carrying a member node id.
#[test]
fn analyzer_rejects_cycle_with_structured_diagnostic() {
    let wl = cyclic_dag(&topo());
    let rep = WorkloadAnalyzer::new().analyze_dag(&wl);
    assert!(!rep.is_clean());
    let d = rep
        .diags
        .iter()
        .find(|d| d.check == "cycle")
        .expect("a cycle diagnostic");
    assert_eq!(d.severity, Severity::Error);
    assert!(d.node.is_some(), "cycle diagnostic names a member node");
    assert!(rep.render().contains("cycle"));
}

/// Acceptance: in a debug build the executor refuses a cyclic DAG
/// before solving anything — `run_dag` panics with the rendered report.
#[test]
#[cfg(debug_assertions)]
fn run_dag_rejects_cyclic_workload_before_solving() {
    let t = topo();
    let wl = cyclic_dag(&t);
    let sim = DesSim::new(&t, DesOpts::default());
    let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
        sim.run_dag(&wl);
    }))
    .expect_err("cyclic DAG must be rejected");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .expect("panic carries the rendered report");
    assert!(
        msg.contains("workload verifier rejected") && msg.contains("cycle"),
        "got {msg:?}"
    );
}

/// A round source that emits one half-sentinel node (`a` keyed, `b`
/// `NO_KEY`) — the exact misuse that silently breaks streamed/staged
/// equivalence.
struct HalfSentinel {
    t: Topology,
    fired: bool,
}

impl RoundSource for HalfSentinel {
    fn next_round(&mut self) -> Option<Vec<StreamNode>> {
        if self.fired {
            return None;
        }
        self.fired = true;
        let mut r = Router::new(&self.t);
        let nics = workload::spread_nics(&self.t, 2);
        Some(vec![StreamNode::Xfer {
            a: 7,
            b: NO_KEY,
            rf: routed(&mut r, nics[0], nics[1], 4096),
            start: 0.0,
        }])
    }

    fn next_round_not_before(&mut self) -> f64 {
        0.0
    }
}

/// Acceptance: key misuse in a streamed round is rejected by the
/// debug-build per-round hook before the round is priced.
#[test]
#[cfg(debug_assertions)]
fn streamed_half_sentinel_is_rejected_before_solving() {
    let t = topo();
    let sim = DesSim::new(&t, DesOpts::default());
    let mut src = HalfSentinel { t: topo(), fired: false };
    let mut scratch = DesScratch::new();
    let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
        sim.session(&mut scratch).stream(&mut src);
    }))
    .expect_err("half-sentinel round must be rejected");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .expect("panic carries the rendered report");
    assert!(
        msg.contains("rejected streamed round")
            && msg.contains("no-key-misuse"),
        "got {msg:?}"
    );
}

// --------------------------------------- collective byte conservation

/// Every `mpi::coll` generator satisfies its closed-form identity at
/// power-of-two, odd, and remainder rank counts.
#[test]
fn coll_generators_satisfy_closed_form_budgets() {
    for p in [4usize, 5, 8, 12] {
        let comm = Comm::world(p);
        let bytes = 1u64 << 20;
        let cases: Vec<(Collective, Vec<Vec<(usize, usize, u64)>>)> = vec![
            (Collective::AllreduceRing, coll::allreduce_ring_rounds(&comm, bytes)),
            (Collective::AllreduceTree, coll::allreduce_tree_rounds(&comm, bytes)),
            (Collective::Alltoall, coll::alltoall_rounds(&comm, bytes)),
            (Collective::Allgather, coll::allgather_rounds(&comm, bytes)),
            (Collective::ReduceScatter, coll::reduce_scatter_rounds(&comm, bytes)),
            (Collective::Bcast, coll::bcast_rounds(&comm, 0, bytes)),
        ];
        for (kind, rounds) in cases {
            let rep = check_collective_rounds(kind, p, bytes, &rounds);
            assert!(
                rep.is_clean(),
                "{kind:?} P={p}: generator fails its own identity:\n{}",
                rep.render()
            );
        }
    }
}

/// The identity is live: dropping one message or doubling one payload
/// breaks conservation and the check says so.
#[test]
fn coll_check_catches_dropped_and_inflated_messages() {
    let comm = Comm::world(8);
    let bytes = 1u64 << 20;

    let mut dropped = coll::allreduce_ring_rounds(&comm, bytes);
    dropped[3].pop();
    let rep =
        check_collective_rounds(Collective::AllreduceRing, 8, bytes, &dropped);
    assert!(rep.errors() > 0, "a dropped message must break the budget");
    assert!(rep.diags.iter().any(|d| d.check == "coll-bytes"));

    let mut inflated = coll::allreduce_ring_rounds(&comm, bytes);
    inflated[0][0].2 *= 2;
    let rep =
        check_collective_rounds(Collective::AllreduceRing, 8, bytes, &inflated);
    assert!(rep.errors() > 0, "a doubled payload must break the budget");

    let mut doubled = coll::alltoall_rounds(&comm, bytes);
    let extra = doubled[0][0];
    doubled[1].push(extra);
    let rep =
        check_collective_rounds(Collective::Alltoall, 8, bytes, &doubled);
    assert!(
        rep.diags.iter().any(|d| {
            d.check == "coll-permutation" || d.check == "coll-bytes"
        }),
        "a repeated ordered pair must be flagged:\n{}",
        rep.render()
    );
}

// ----------------------------------------------- campaign lint surface

/// `Scenario::lint` (the `aurorasim lint` verb's engine) reports zero
/// errors on every standard-campaign scenario: the severity calibration
/// keeps real workloads warning-only.
#[test]
fn standard_campaign_lints_clean() {
    let c = Campaign::standard(&AuroraConfig::small(8, 4), 42);
    assert!(!c.scenarios.is_empty());
    for s in &c.scenarios {
        let t = Topology::new(&s.cfg);
        let rep = s.lint(&t, 16);
        assert_eq!(
            rep.errors(),
            0,
            "scenario {}: lint found errors:\n{}",
            s.name,
            rep.render()
        );
        assert!(rep.nodes > 0, "scenario {}: lint saw no nodes", s.name);
    }
}

/// The open-loop (streaming) lint path: a small OpenLoop scenario
/// analyzes its own arrival stream prefix without errors.
#[test]
fn open_loop_scenario_lints_clean_via_round_source() {
    let s = Scenario::new(
        "ol_lint",
        AuroraConfig::small(4, 4),
        DesOpts::default(),
        Workload::OpenLoop {
            arrivals: 500,
            rate: 50_000.0,
            endpoints: 64,
            mix: vec![
                RpcClass { bytes: 4 << 10, weight: 0.7 },
                RpcClass { bytes: 64 << 10, weight: 0.3 },
            ],
            quantum: 1e-3,
            window: 10e-3,
            bw_multiplier: 1.0,
            link_fraction: 0.0,
        },
        9,
    );
    let t = Topology::new(&s.cfg);
    let rep = s.lint(&t, 64);
    assert_eq!(rep.errors(), 0, "open-loop lint errors:\n{}", rep.render());
    assert!(rep.rounds > 0, "the streaming path analyzed no rounds");
}
