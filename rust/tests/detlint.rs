//! The determinism lint as a test: `cargo test` fails whenever
//! `rust/src/` violates a detlint rule (same engine and allowlist as
//! the `detlint` binary / CI job), and the fixture sweep proves every
//! rule class actually fires on deliberately-violating code — a lint
//! that can't catch its own fixtures is decoration.

use aurorasim::util::detlint::{scan_source, scan_tree, Allowlist};
use std::fs;
use std::path::{Path, PathBuf};

fn manifest() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn repo_allowlist() -> Allowlist {
    let p = manifest().join("..").join("ci").join("detlint_allow.txt");
    Allowlist::parse(&fs::read_to_string(p).expect("ci/detlint_allow.txt"))
}

fn scan_fixture(name: &str) -> Vec<String> {
    let p = manifest()
        .join("tests")
        .join("fixtures")
        .join("detlint")
        .join(name);
    let src = fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("fixture {name}: {e}"));
    // fixtures are scanned as if they lived in the strictest scope
    let mut diags = Vec::new();
    scan_source(
        &format!("fabric/{name}"),
        &src,
        &Allowlist::default(),
        &mut diags,
    );
    diags.iter().map(|d| d.rule.to_string()).collect()
}

/// The tree is lint-clean modulo the reviewed allowlist — the same
/// check the blocking CI job runs.
#[test]
fn src_tree_is_clean_under_allowlist() {
    let res = scan_tree(&manifest().join("src"), &repo_allowlist());
    assert!(res.files > 30, "walked only {} files", res.files);
    let rendered: Vec<String> =
        res.diags.iter().map(|d| d.render()).collect();
    assert!(
        res.diags.is_empty(),
        "detlint violations in src/:\n{}",
        rendered.join("\n")
    );
}

/// The allowlist is live, minimal and exact: with it removed, scanning
/// the tree yields findings that are ALL covered by entries — no stale
/// entry permits nothing, no finding lacks an entry.
#[test]
fn allowlist_is_live_and_minimal() {
    let allow = repo_allowlist();
    assert!(!allow.is_empty(), "expected at least one reviewed exception");
    let res = scan_tree(&manifest().join("src"), &Allowlist::default());
    assert!(
        !res.diags.is_empty(),
        "allowlist has entries but an unfiltered scan finds nothing — \
         delete the stale entries"
    );
    for d in &res.diags {
        assert!(
            allow.permits(d.rule, &d.path, &d.text),
            "unfiltered finding not covered by ci/detlint_allow.txt:\n{}",
            d.render()
        );
    }
}

/// Every rule class fires on its deliberately-violating fixture.
#[test]
fn every_rule_fires_on_its_fixture() {
    for (fixture, rule, min_hits) in [
        ("std_hash_container.rs", "std-hash-container", 2),
        ("wall_clock.rs", "wall-clock", 2),
        ("thread_spawn.rs", "thread-spawn", 1),
        ("hash_iter_float_reduce.rs", "hash-iter-float-reduce", 3),
        ("f32_rate.rs", "f32-rate", 2),
    ] {
        let rules = scan_fixture(fixture);
        let hits = rules.iter().filter(|r| r.as_str() == rule).count();
        assert!(
            hits >= min_hits,
            "{fixture}: expected >= {min_hits} {rule} hit(s), got {hits} \
             (all: {rules:?})"
        );
    }
}

/// Outside the `fabric/`/`campaign/` scope the scoped rules stay quiet
/// (the fixtures only violate when placed in the strict scope), while
/// the everywhere-rules still fire.
#[test]
fn scoped_rules_respect_directory_scope() {
    let p = manifest()
        .join("tests")
        .join("fixtures")
        .join("detlint")
        .join("f32_rate.rs");
    let src = fs::read_to_string(p).unwrap();
    let mut diags = Vec::new();
    scan_source("runtime/f32_rate.rs", &src, &Allowlist::default(), &mut diags);
    assert!(
        diags.is_empty(),
        "f32 outside fabric//campaign/ must not fire: {:?}",
        diags.iter().map(|d| d.rule).collect::<Vec<_>>()
    );
}

/// The binary's allowlist path resolves from the crate manifest — keep
/// the file parseable (comments + format discipline).
#[test]
fn allowlist_file_parses_every_entry() {
    let p = manifest().join("..").join("ci").join("detlint_allow.txt");
    let text = fs::read_to_string(p).unwrap();
    let parsed = Allowlist::parse(&text);
    let non_comment = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .count();
    assert_eq!(
        parsed.len(),
        non_comment,
        "every non-comment allowlist line must parse as rule|path|needle"
    );
}

/// Fixture hygiene: the fixture directory exists and is never reachable
/// by the src tree walk (fixtures must not make the clean-tree check
/// fail).
#[test]
fn fixtures_live_outside_the_scanned_tree() {
    let fixtures = manifest().join("tests").join("fixtures").join("detlint");
    assert!(fixtures.is_dir());
    assert!(!fixtures.starts_with(Path::new(&manifest().join("src"))));
}
