//! Golden regression fixtures for the reproduction harness.
//!
//! `rust/tests/golden/reproduce.json` pins the headline scalar of each
//! `reproduce::` experiment (JSON snapshot with a per-metric relative
//! tolerance) so a perf refactor cannot silently shift the numbers the
//! paper reproduction reports. Regenerate after an intentional model
//! change with:
//!
//! ```bash
//! UPDATE_GOLDEN=1 cargo test -q --test golden_reproduce
//! ```
//!
//! Metrics computed by `reproduce::key_metrics()` but absent from the
//! fixture (e.g. newly added scenarios before their first regeneration)
//! produce a warning, not a failure, so adding metrics never breaks CI;
//! metrics *in* the fixture must exist and match.

use aurorasim::reproduce;
use aurorasim::runtime::manifest::RunInfo;
use aurorasim::util::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

const SCHEMA: &str = "aurorasim.golden/v1";
const DEFAULT_RTOL: f64 = 0.05;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("reproduce.json")
}

fn load_golden() -> Option<BTreeMap<String, (f64, f64)>> {
    let text = std::fs::read_to_string(golden_path()).ok()?;
    let root = Json::parse(&text).expect("golden fixture must be valid JSON");
    RunInfo::check(&root, SCHEMA).expect("golden fixture schema");
    let metrics = root
        .get("metrics")
        .and_then(Json::as_obj)
        .expect("golden fixture missing 'metrics'");
    Some(
        metrics
            .iter()
            .map(|(k, v)| {
                let value = v
                    .get("value")
                    .and_then(Json::as_f64)
                    .unwrap_or_else(|| panic!("{k}: missing value"));
                let rtol = v
                    .get("rtol")
                    .and_then(Json::as_f64)
                    .unwrap_or(DEFAULT_RTOL);
                (k.clone(), (value, rtol))
            })
            .collect(),
    )
}

fn write_golden(
    computed: &[(&'static str, f64)],
    old: &BTreeMap<String, (f64, f64)>,
) {
    let metrics = Json::Obj(
        computed
            .iter()
            .map(|(k, v)| {
                let rtol =
                    old.get(*k).map(|(_, r)| *r).unwrap_or(DEFAULT_RTOL);
                (
                    k.to_string(),
                    Json::obj(vec![
                        ("value", Json::num(*v)),
                        ("rtol", Json::num(rtol)),
                    ]),
                )
            })
            .collect(),
    );
    let root = Json::obj(vec![
        ("info", RunInfo::new(SCHEMA).to_json()),
        ("metrics", metrics),
    ]);
    let path = golden_path();
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, root.dump_pretty()).unwrap();
    eprintln!("golden fixture regenerated at {}", path.display());
}

#[test]
fn reproduce_metrics_match_golden() {
    let computed = reproduce::key_metrics();
    let golden = load_golden().unwrap_or_default();
    if std::env::var_os("UPDATE_GOLDEN").is_some() {
        write_golden(&computed, &golden);
        return;
    }
    assert!(
        !golden.is_empty(),
        "missing golden fixture {} — run UPDATE_GOLDEN=1 cargo test \
         --test golden_reproduce",
        golden_path().display()
    );
    let by_key: BTreeMap<&str, f64> =
        computed.iter().map(|(k, v)| (*k, *v)).collect();
    let mut failures = Vec::new();
    for (key, (want, rtol)) in &golden {
        match by_key.get(key.as_str()) {
            None => failures.push(format!(
                "{key}: in golden fixture but no longer computed"
            )),
            Some(got) => {
                let rel = (got - want).abs() / want.abs().max(1e-30);
                if rel > *rtol {
                    failures.push(format!(
                        "{key}: measured {got:.6e} vs golden {want:.6e} \
                         (rel {rel:.3} > rtol {rtol})"
                    ));
                }
            }
        }
    }
    for (key, _) in &computed {
        if !golden.contains_key(*key) {
            eprintln!(
                "note: metric '{key}' not pinned yet — regenerate with \
                 UPDATE_GOLDEN=1 to track it"
            );
        }
    }
    assert!(failures.is_empty(), "golden drift:\n{}", failures.join("\n"));
}
