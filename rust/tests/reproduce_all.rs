//! Paper-vs-measured integration assertions: every headline number of the
//! evaluation section must land within tolerance of the paper's value,
//! and every experiment id must produce a report.

use aurorasim::apps;
use aurorasim::config::AuroraConfig;
use aurorasim::reproduce;

fn within(measured: f64, paper: f64, tol: f64, what: &str) {
    let err = (measured - paper).abs() / paper;
    assert!(
        err < tol,
        "{what}: measured {measured:.4e} vs paper {paper:.4e} ({:.1}% off)",
        err * 100.0
    );
}

#[test]
fn headline_hpl() {
    let cfg = AuroraConfig::aurora();
    let run = apps::hpl::performance(&cfg, 9234);
    within(run.rate, 1.012e18, 0.05, "HPL rate @9234");
    within(run.efficiency, 0.7884, 0.04, "HPL efficiency @9234");
    // paper runtime 4h21m54s = 15714 s
    within(run.time, 15714.0, 0.25, "HPL runtime @9234");
}

#[test]
fn headline_table2_all_rows() {
    let cfg = AuroraConfig::aurora();
    let paper: [(usize, f64); 9] = [
        (9234, 1012.0),
        (8748, 954.43),
        (8632, 949.02),
        (8109, 873.78),
        (8058, 865.93),
        (7200, 805.24),
        (6888, 764.04),
        (6273, 688.99),
        (5439, 585.43),
    ];
    for (nodes, pf) in paper {
        let run = apps::hpl::performance(&cfg, nodes);
        within(run.rate / 1e15, pf, 0.06, &format!("HPL @{nodes}"));
    }
}

#[test]
fn headline_hpl_mxp() {
    let cfg = AuroraConfig::aurora();
    let run = apps::hpl_mxp::performance(&cfg, 9500);
    within(run.rate, 11.64e18, 0.08, "HPL-MxP rate @9500");
}

#[test]
fn headline_graph500() {
    let cfg = AuroraConfig::aurora();
    let run = apps::graph500::performance(&cfg, 8192, 42);
    within(run.gteps, 69_373.0, 0.10, "Graph500 GTEPS");
}

#[test]
fn headline_hpcg() {
    let cfg = AuroraConfig::aurora();
    let run = apps::hpcg::performance(&cfg, 4096);
    within(run.pflops, 5.613, 0.10, "HPCG PF/s");
}

#[test]
fn headline_alltoall_peak() {
    let cfg = AuroraConfig::aurora();
    let peak = apps::alltoall::Alltoall::paper().peak(&cfg);
    within(peak / 1e12, 228.92, 0.10, "Fig 4 all2all peak TB/s");
}

#[test]
fn headline_weak_scaling_bands() {
    let cfg = AuroraConfig::aurora();
    // HACC: 99% @1024, 97% @8192 (Fig 17)
    let hacc = apps::hacc::fig17(&cfg);
    assert!((hacc[1].efficiency - 0.99).abs() < 0.025, "HACC@1024 {}",
        hacc[1].efficiency);
    assert!((hacc[2].efficiency - 0.97).abs() < 0.035, "HACC@8192 {}",
        hacc[2].efficiency);
    // Nekbone: >95% at 4096 (Fig 18)
    let nek = apps::nekbone::fig18(&cfg, &[128, 4096]);
    assert!(nek[1].efficiency > 0.95, "Nekbone {}", nek[1].efficiency);
    // LAMMPS: >85% at 9216 (Fig 20)
    let lmp = apps::lammps::fig20(&cfg, &[128, 9216]);
    assert!(lmp[1].efficiency > 0.85, "LAMMPS {}", lmp[1].efficiency);
}

#[test]
fn every_experiment_produces_a_report() {
    for id in reproduce::all_ids() {
        // fig5/table5/table6 run reduced-scale simulations — still bounded
        let out = reproduce::run(id)
            .unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(out.contains("paper:"), "{id} missing paper reference");
        assert!(out.len() > 80, "{id} suspiciously short: {out}");
    }
}

#[test]
fn fmm_tables_shapes() {
    use aurorasim::machine::Machine;
    use aurorasim::mpi::rma::RmaKind;
    let m = Machine::new(&AuroraConfig::small(4, 8));
    let scale = 0.01;
    let get_h = apps::fmm::table(&m, RmaKind::Get, true, scale).unwrap();
    let get_n = apps::fmm::table(&m, RmaKind::Get, false, scale).unwrap();
    let put_h = apps::fmm::table(&m, RmaKind::Put, true, scale).unwrap();
    // Get+HMEM rows in the right band (paper 0.9/1.1/1.6 s)
    for (row, paper) in get_h.iter().zip([0.9, 1.1, 1.6, 14.5]) {
        let ratio = row.time / paper;
        assert!((0.4..2.5).contains(&ratio), "{}: {} vs {paper}",
            row.label, row.time);
    }
    // without-HMEM Get decreases with ranks (paper 24.6 -> 13.0)
    assert!(get_n[2].time < get_n[0].time);
    // Put ~10x Get
    assert!(put_h[0].time > 5.0 * get_h[0].time);
}
