//! End-to-end functional validation through the PJRT artifacts: requires
//! `make artifacts` (skipped with a notice otherwise so `cargo test`
//! stays runnable from a clean checkout).

use aurorasim::config::AuroraConfig;
use aurorasim::coordinator::{JobSpec, Launcher};
use aurorasim::machine::Machine;
use aurorasim::mpi::{coll, Comm};
use aurorasim::reproduce;
use aurorasim::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts — run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn full_functional_suite_passes() {
    let Some(mut rt) = runtime() else { return };
    let report = reproduce::functional_suite(&mut rt).expect("suite");
    assert!(report.contains("PASS < 16"), "HPL residual: {report}");
    assert!(report.contains("validation PASS"), "BFS: {report}");
    assert!(report.contains("data integrity PASS"), "FMM: {report}");
}

#[test]
fn artifacts_manifest_complete() {
    let Some(rt) = runtime() else { return };
    for name in [
        "hpl_panel_factor", "hpl_trsm_row", "hpl_trsm_col", "hpl_update",
        "hpl_residual", "mxp_update", "mxp_ir_step", "mxp_gemm",
        "hpcg_spmv", "hpcg_symgs", "hpcg_dot", "hpcg_waxpby",
        "hacc_fft_poisson", "hacc_short_range", "nekbone_ax",
        "lammps_pair_tile",
    ] {
        assert!(rt.manifest.get(name).is_some(), "missing artifact {name}");
        assert!(rt.flops(name) > 0.0, "{name} has no flop estimate");
    }
}

#[test]
fn every_artifact_compiles_and_executes() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> =
        rt.manifest.names().map(str::to_string).collect();
    for name in names {
        let spec = rt.manifest.get(&name).unwrap().clone();
        let args: Vec<Vec<f64>> = spec
            .args
            .iter()
            .map(|a| {
                let mut v = vec![0.5; a.elems()];
                // square matrices get diagonal dominance so LU/solve
                // artifacts stay non-singular on this generic probe
                if a.shape.len() == 2 && a.shape[0] == a.shape[1] {
                    let n = a.shape[0];
                    for i in 0..n {
                        v[i * n + i] += n as f64;
                    }
                }
                v
            })
            .collect();
        let refs: Vec<&[f64]> = args.iter().map(|v| v.as_slice()).collect();
        let out = rt
            .call_f64(&name, &refs)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.len(), spec.outputs.len(), "{name} output arity");
        for (o, os) in out.iter().zip(&spec.outputs) {
            assert_eq!(o.len(), os.elems(), "{name} output length");
            assert!(
                o.iter().all(|v| v.is_finite()),
                "{name} produced non-finite values"
            );
        }
    }
}

#[test]
fn launcher_end_to_end_with_compute() {
    let Some(mut rt) = runtime() else { return };
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let mut l = Launcher::new(&m);
    let spec = JobSpec::new("stencil+allreduce", 8, 1);
    let rep = l
        .launch(&spec, |w| {
            // one SpMV per rank through PJRT + a reduction through the
            // fabric — the minimal all-layers round trip
            let padded = vec![1.0f64; 34 * 34 * 34];
            let mut acc = 0.0;
            for _ in 0..w.size() {
                let out = rt.call_f32("hpcg_spmv", &[&padded]).unwrap();
                acc += out[0][0];
            }
            coll::allreduce(w, &Comm::world(8), 8);
            acc
        })
        .unwrap();
    // interior of a constant-1 field: 26 - 26 = 0; corner sees fewer
    // neighbours => value > 0. Just check determinism & finiteness:
    assert!(rep.result.is_finite());
    assert!(rep.elapsed > 0.0);
}
