//! PR-7 open-loop service tier: bounded-memory proof, trace-vs-`run_dag`
//! equivalence, and campaign determinism for the steady-state report.
//!
//! * Memory bound: growing the trace 100x must leave the streaming
//!   executor's peak-live node window flat (live = offered load x
//!   latency plus one materialization quantum, never trace length) —
//!   the test-scale twin of the gated `des_open_loop_steady` bench and
//!   its `open_loop_live_headroom >= 50` floor.
//! * Exactness: open-loop arrival floors sit inside their
//!   materialization windows, so nothing releases late and a short
//!   trace is 1e-9-equivalent to `run_dag` over
//!   `DagWorkload::from_timed` on the identical routed transfers.
//! * Determinism: the open-loop campaign scenario serializes to
//!   byte-identical JSON at every DES solver-thread count.

use aurorasim::campaign::{Campaign, Scenario, Workload};
use aurorasim::config::AuroraConfig;
use aurorasim::fabric::arrivals::OpenLoopSource;
use aurorasim::fabric::des::{DesOpts, DesScratch, DesSim, TimedFlow};
use aurorasim::fabric::{
    run_open_loop, workload, Arrival, ArrivalSource, Flow, PoissonArrivals,
    RoundSource, Router, RoutedFlow, RpcClass, StreamNode, TraceArrivals,
};
use aurorasim::topology::Topology;

fn mix() -> Vec<RpcClass> {
    vec![
        RpcClass { bytes: 4 << 10, weight: 0.7 },
        RpcClass { bytes: 64 << 10, weight: 0.3 },
    ]
}

#[test]
fn peak_live_stays_flat_as_trace_grows_100x() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 64);
    let sim = DesSim::new(&t, DesOpts::default());
    let mut scratch = DesScratch::new();
    let mut run = |n: u64| {
        let mut router = Router::with_seed(&t, 7);
        let src =
            PoissonArrivals::new(7, 100_000.0, n, nics.clone(), mix());
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3)
    };
    let (small, _) = run(1_000);
    let (big, ss) = run(100_000);
    assert_eq!(big.total_nodes, 100_000, "every arrival materializes");
    assert_eq!(ss.completed, 100_000, "every arrival retires");
    assert_eq!(big.late_releases, 0, "arrival floors are never late");
    assert!(
        big.peak_live_nodes <= small.peak_live_nodes * 4,
        "100x arrivals must not grow the live window \
         (peak {} at 100k vs {} at 1k)",
        big.peak_live_nodes,
        small.peak_live_nodes
    );
    let headroom = big.total_nodes as f64 / big.peak_live_nodes as f64;
    assert!(
        headroom >= 50.0,
        "live-node headroom {headroom:.1} below the CI floor \
         (peak {} of {})",
        big.peak_live_nodes,
        big.total_nodes
    );
    // steady-state sanity on the big run
    assert!(ss.duration > 0.0 && ss.throughput_flows > 0.0);
    assert!(ss.p50 > 0.0 && ss.p50 <= ss.p99 && ss.p99 <= ss.p999);
    assert!(ss.peak_inflight >= 1);
    assert_eq!(ss.max_backlog.len(), 2, "one backlog slot per mix class");
    assert!(ss.windows > 0);
}

#[test]
fn short_trace_matches_run_dag_on_materialized_equivalent() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 32);
    // generate a Poisson arrival set, round-trip it through the
    // text trace format (f64 Display is shortest-round-trip, so the
    // parsed times are bit-identical)
    let mut gen = PoissonArrivals::new(21, 5_000.0, 400, nics, mix());
    let mut trace = String::from("# t src dst bytes class\n");
    let mut arrivals: Vec<Arrival> = Vec::new();
    while let Some(a) = gen.next_arrival() {
        trace.push_str(&format!(
            "{} {} {} {} {}\n",
            a.t, a.src, a.dst, a.bytes, a.class
        ));
        arrivals.push(a);
    }
    assert_eq!(arrivals.len(), 400);

    let sim = DesSim::new(&t, DesOpts::default());

    // path A: the trace reader through the streaming open-loop tier
    let mut router_a = Router::with_seed(&t, 99);
    let mut finish = vec![f64::NAN; arrivals.len()];
    let res = {
        let src = TraceArrivals::new(trace.as_bytes());
        let mut ol = OpenLoopSource::new(src, &mut router_a, 1e-3);
        sim.session(&mut DesScratch::new())
            .stream_sink(&mut ol, |id, tf| finish[id as usize] = tf)
    };
    assert_eq!(res.total_nodes, arrivals.len());
    assert_eq!(res.late_releases, 0);

    // path B: the same transfers, routed identically, fully
    // materialized and run closed-loop
    let mut router_b = Router::with_seed(&t, 99);
    let timed: Vec<TimedFlow> = arrivals
        .iter()
        .map(|a| {
            let f = Flow::new(a.src, a.dst, a.bytes);
            TimedFlow {
                rf: RoutedFlow { path: router_b.route(&f), flow: f },
                start: a.t,
            }
        })
        .collect();
    let dag = sim.run_dag(&aurorasim::fabric::DagWorkload::from_timed(&timed));
    assert!((res.makespan - dag.makespan).abs()
        / dag.makespan.abs().max(1e-30)
        < 1e-9);
    for (i, (a, b)) in finish.iter().zip(&dag.node_finish).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(rel < 1e-9, "arrival {i}: stream {a} vs dag {b}");
    }
}

#[test]
fn open_loop_scenario_json_is_identical_across_solver_threads() {
    let scenario = |threads: usize| {
        Scenario::new(
            "ol_det",
            AuroraConfig::small(4, 4),
            DesOpts { solver_threads: threads, ..DesOpts::default() },
            Workload::OpenLoop {
                arrivals: 2_000,
                rate: 50_000.0,
                endpoints: 64,
                mix: mix(),
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 1.0,
                link_fraction: 0.0,
            },
            9,
        )
    };
    let report = |threads: usize, workers: usize| {
        let c = Campaign { scenarios: vec![scenario(threads)] };
        c.run(workers).to_json().dump_pretty()
    };
    let serial = report(1, 1);
    let fanned = report(8, 2);
    assert_eq!(
        serial, fanned,
        "open-loop steady-state report must be byte-identical across \
         DES solver-thread counts"
    );
    assert!(serial.contains("\"p999_s\""));
    assert!(serial.contains("\"peak_live\""));
}

// ------------------------------------------- trace parser diagnostics

/// Drain a trace through the parser and return the panic message it
/// dies with. Builds the reader inside the closure so the unwind can't
/// leave a poisoned source behind.
fn parse_panic(trace: &'static str, bound: Option<u32>) -> String {
    let err = std::panic::catch_unwind(|| {
        let mut src = TraceArrivals::new(trace.as_bytes());
        if let Some(b) = bound {
            src = src.with_endpoint_bound(b);
        }
        while src.next_arrival().is_some() {}
    })
    .expect_err("malformed trace must be rejected");
    err.downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .expect("panic payload is a message")
}

/// Every malformed-trace class dies with a message naming the 1-based
/// physical line (comments and blanks count) and the offending field —
/// a corrupt trace must fail loudly at parse time, never misprice.
#[test]
fn trace_parse_errors_name_the_line_and_field() {
    for (trace, expect) in [
        // truncated record: dst missing
        ("0.5 3\n", "trace line 1: missing dst"),
        // non-numeric bytes field
        ("0.5 3 4 lots\n", "trace line 1: bad bytes"),
        // NaN parses as a valid f64 but is not a valid timestamp
        ("NaN 3 4 1024\n", "trace line 1: non-finite timestamp NaN"),
        // negative start: decreases below the initial floor of 0
        ("-1 3 4 64\n", "trace line 1: timestamp -1 decreases (last 0)"),
        // time travel on the second record
        (
            "1.0 0 1 64\n0.5 0 1 64\n",
            "trace line 2: timestamp 0.5 decreases (last 1)",
        ),
        // self-flow
        ("0.0 5 5 64\n", "trace line 1: src == dst"),
        // line numbers are physical: header + blank push this to line 3
        (
            "# t src dst bytes\n\n2.0 1 1 64\n",
            "trace line 3: src == dst",
        ),
    ] {
        let msg = parse_panic(trace, None);
        assert!(
            msg.contains(expect),
            "trace {trace:?}: expected {expect:?} in panic, got {msg:?}"
        );
    }
}

/// With an endpoint bound installed (the topology's compute-endpoint
/// count), a rank-mangled trace fails at its line instead of panicking
/// deep inside the router.
#[test]
fn trace_endpoint_bound_rejects_out_of_range_ranks() {
    let msg = parse_panic("0.0 7 999 64\n", Some(64));
    assert!(
        msg.contains("trace line 1: dst 999 out of range (endpoints < 64)"),
        "got {msg:?}"
    );
    // in-range ids pass under the same bound
    let mut ok = TraceArrivals::new("0.0 7 63 64\n".as_bytes())
        .with_endpoint_bound(64);
    let a = ok.next_arrival().unwrap();
    assert_eq!((a.src, a.dst, a.bytes), (7, 63, 64));
    assert!(ok.next_arrival().is_none());
}

// ------------------------------------- sparse-window deadlock freedom

/// Arrival gaps thousands of quanta wide must not produce empty
/// rounds: `OpenLoopSource` anchors each round on a real arrival and
/// `next_round_not_before` jumps straight to the next occupied window.
/// (An empty throttled round would spin `materialize_next_round`
/// without advancing time — the exact hazard the workload verifier
/// flags as an `empty-round` error.)
#[test]
fn sparse_arrivals_skip_empty_windows_without_deadlock() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let mut router = Router::with_seed(&t, 5);
    // four arrivals, 1 ms quantum: ~10 000 empty windows between each
    // cluster; the middle two share one window
    let trace = "1e-4 0 1 4096\n10.0 2 3 4096\n10.00005 4 5 4096\n\
                 20.0 6 7 4096\n";
    let src = TraceArrivals::new(trace.as_bytes());
    let mut ol = OpenLoopSource::new(src, &mut router, 1e-3);
    let mut windows = Vec::new();
    let mut nodes = 0usize;
    loop {
        let nb = ol.next_round_not_before();
        let Some(round) = ol.next_round() else { break };
        assert!(!round.is_empty(), "rounds anchor on a real arrival");
        for n in &round {
            let start = match n {
                StreamNode::Compute { start, .. }
                | StreamNode::Xfer { start, .. } => *start,
            };
            assert!(
                start >= nb,
                "floor {start} precedes its announced window {nb}"
            );
        }
        windows.push(nb);
        nodes += round.len();
    }
    assert_eq!(nodes, 4, "every arrival materializes exactly once");
    assert_eq!(
        windows,
        vec![0.0, 10.0, 20.0],
        "not-before jumps occupied window to occupied window"
    );
    assert_eq!(
        ol.next_round_not_before(),
        0.0,
        "exhausted source reports no deferral"
    );

    // end-to-end: the same sparse trace runs through the streaming
    // executor with zero late releases
    let mut router = Router::with_seed(&t, 5);
    let src = TraceArrivals::new(trace.as_bytes()).with_endpoint_bound(64);
    let sim = DesSim::new(&t, DesOpts::default());
    let mut ol = OpenLoopSource::new(src, &mut router, 1e-3);
    let res = sim.session(&mut DesScratch::new()).stream(&mut ol);
    assert_eq!(res.total_nodes, 4);
    assert_eq!(res.late_releases, 0, "sparse windows never release late");
    assert!(res.makespan > 20.0, "the final arrival at t=20 s completes");
}
