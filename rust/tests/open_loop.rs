//! PR-7 open-loop service tier: bounded-memory proof, trace-vs-`run_dag`
//! equivalence, and campaign determinism for the steady-state report.
//!
//! * Memory bound: growing the trace 100x must leave the streaming
//!   executor's peak-live node window flat (live = offered load x
//!   latency plus one materialization quantum, never trace length) —
//!   the test-scale twin of the gated `des_open_loop_steady` bench and
//!   its `open_loop_live_headroom >= 50` floor.
//! * Exactness: open-loop arrival floors sit inside their
//!   materialization windows, so nothing releases late and a short
//!   trace is 1e-9-equivalent to `run_dag` over
//!   `DagWorkload::from_timed` on the identical routed transfers.
//! * Determinism: the open-loop campaign scenario serializes to
//!   byte-identical JSON at every DES solver-thread count.

use aurorasim::campaign::{Campaign, Scenario, Workload};
use aurorasim::config::AuroraConfig;
use aurorasim::fabric::arrivals::OpenLoopSource;
use aurorasim::fabric::des::{DesOpts, DesScratch, DesSim, TimedFlow};
use aurorasim::fabric::{
    run_open_loop, workload, Arrival, ArrivalSource, Flow, PoissonArrivals,
    Router, RoutedFlow, RpcClass, TraceArrivals,
};
use aurorasim::topology::Topology;

fn mix() -> Vec<RpcClass> {
    vec![
        RpcClass { bytes: 4 << 10, weight: 0.7 },
        RpcClass { bytes: 64 << 10, weight: 0.3 },
    ]
}

#[test]
fn peak_live_stays_flat_as_trace_grows_100x() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 64);
    let sim = DesSim::new(&t, DesOpts::default());
    let mut scratch = DesScratch::new();
    let mut run = |n: u64| {
        let mut router = Router::with_seed(&t, 7);
        let src =
            PoissonArrivals::new(7, 100_000.0, n, nics.clone(), mix());
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3)
    };
    let (small, _) = run(1_000);
    let (big, ss) = run(100_000);
    assert_eq!(big.total_nodes, 100_000, "every arrival materializes");
    assert_eq!(ss.completed, 100_000, "every arrival retires");
    assert_eq!(big.late_releases, 0, "arrival floors are never late");
    assert!(
        big.peak_live_nodes <= small.peak_live_nodes * 4,
        "100x arrivals must not grow the live window \
         (peak {} at 100k vs {} at 1k)",
        big.peak_live_nodes,
        small.peak_live_nodes
    );
    let headroom = big.total_nodes as f64 / big.peak_live_nodes as f64;
    assert!(
        headroom >= 50.0,
        "live-node headroom {headroom:.1} below the CI floor \
         (peak {} of {})",
        big.peak_live_nodes,
        big.total_nodes
    );
    // steady-state sanity on the big run
    assert!(ss.duration > 0.0 && ss.throughput_flows > 0.0);
    assert!(ss.p50 > 0.0 && ss.p50 <= ss.p99 && ss.p99 <= ss.p999);
    assert!(ss.peak_inflight >= 1);
    assert_eq!(ss.max_backlog.len(), 2, "one backlog slot per mix class");
    assert!(ss.windows > 0);
}

#[test]
fn short_trace_matches_run_dag_on_materialized_equivalent() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 32);
    // generate a Poisson arrival set, round-trip it through the
    // text trace format (f64 Display is shortest-round-trip, so the
    // parsed times are bit-identical)
    let mut gen = PoissonArrivals::new(21, 5_000.0, 400, nics, mix());
    let mut trace = String::from("# t src dst bytes class\n");
    let mut arrivals: Vec<Arrival> = Vec::new();
    while let Some(a) = gen.next_arrival() {
        trace.push_str(&format!(
            "{} {} {} {} {}\n",
            a.t, a.src, a.dst, a.bytes, a.class
        ));
        arrivals.push(a);
    }
    assert_eq!(arrivals.len(), 400);

    let sim = DesSim::new(&t, DesOpts::default());

    // path A: the trace reader through the streaming open-loop tier
    let mut router_a = Router::with_seed(&t, 99);
    let mut finish = vec![f64::NAN; arrivals.len()];
    let res = {
        let src = TraceArrivals::new(trace.as_bytes());
        let mut ol = OpenLoopSource::new(src, &mut router_a, 1e-3);
        sim.session(&mut DesScratch::new())
            .stream_sink(&mut ol, |id, tf| finish[id as usize] = tf)
    };
    assert_eq!(res.total_nodes, arrivals.len());
    assert_eq!(res.late_releases, 0);

    // path B: the same transfers, routed identically, fully
    // materialized and run closed-loop
    let mut router_b = Router::with_seed(&t, 99);
    let timed: Vec<TimedFlow> = arrivals
        .iter()
        .map(|a| {
            let f = Flow::new(a.src, a.dst, a.bytes);
            TimedFlow {
                rf: RoutedFlow { path: router_b.route(&f), flow: f },
                start: a.t,
            }
        })
        .collect();
    let dag = sim.run_dag(&aurorasim::fabric::DagWorkload::from_timed(&timed));
    assert!((res.makespan - dag.makespan).abs()
        / dag.makespan.abs().max(1e-30)
        < 1e-9);
    for (i, (a, b)) in finish.iter().zip(&dag.node_finish).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(rel < 1e-9, "arrival {i}: stream {a} vs dag {b}");
    }
}

#[test]
fn open_loop_scenario_json_is_identical_across_solver_threads() {
    let scenario = |threads: usize| {
        Scenario::new(
            "ol_det",
            AuroraConfig::small(4, 4),
            DesOpts { solver_threads: threads, ..DesOpts::default() },
            Workload::OpenLoop {
                arrivals: 2_000,
                rate: 50_000.0,
                endpoints: 64,
                mix: mix(),
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 1.0,
                link_fraction: 0.0,
            },
            9,
        )
    };
    let report = |threads: usize, workers: usize| {
        let c = Campaign { scenarios: vec![scenario(threads)] };
        c.run(workers).to_json().dump_pretty()
    };
    let serial = report(1, 1);
    let fanned = report(8, 2);
    assert_eq!(
        serial, fanned,
        "open-loop steady-state report must be byte-identical across \
         DES solver-thread counts"
    );
    assert!(serial.contains("\"p999_s\""));
    assert!(serial.contains("\"peak_live\""));
}
