//! PR-7 open-loop service tier: bounded-memory proof, trace-vs-`run_dag`
//! equivalence, and campaign determinism for the steady-state report.
//!
//! * Memory bound: growing the trace 100x must leave the streaming
//!   executor's peak-live node window flat (live = offered load x
//!   latency plus one materialization quantum, never trace length) —
//!   the test-scale twin of the gated `des_open_loop_steady` bench and
//!   its `open_loop_live_headroom >= 50` floor.
//! * Exactness: open-loop arrival floors sit inside their
//!   materialization windows, so nothing releases late and a short
//!   trace is 1e-9-equivalent to `run_dag` over
//!   `DagWorkload::from_timed` on the identical routed transfers.
//! * Determinism: the open-loop campaign scenario serializes to
//!   byte-identical JSON at every DES solver-thread count.
//!
//! PR-10 graceful degradation rides the same tier, so its suite lives
//! here too: fault-failed flows retire through the collector (no
//! phantom backlog, clean quantiles), an armed-but-inert
//! [`ServicePolicy`] is bit-identical to no policy, a brownout
//! (faults + policy) scenario serializes byte-identically at every
//! solver-thread count, the brownout acceptance property (policy keeps
//! accepted p99 bounded and backlog flat under a mid-run flap while
//! the unprotected run's backlog grows with offered load), and hedged
//! requests completing on a disjoint route.

use aurorasim::campaign::{Campaign, Scenario, Workload};
use aurorasim::config::AuroraConfig;
use aurorasim::fabric::arrivals::OpenLoopSource;
use aurorasim::fabric::des::{DesOpts, DesScratch, DesSim, TimedFlow};
use aurorasim::fabric::faults::{FaultKind, FaultPolicy, FaultSchedule};
use aurorasim::fabric::{
    brownout_policy, run_open_loop, workload, Arrival, ArrivalSource,
    ClassPolicy, Flow, PoissonArrivals, RoundSource, Router, RoutedFlow,
    RpcClass, ServicePolicy, StreamNode, TraceArrivals,
};
use aurorasim::topology::{LinkId, Topology};

fn mix() -> Vec<RpcClass> {
    vec![
        RpcClass { bytes: 4 << 10, weight: 0.7 },
        RpcClass { bytes: 64 << 10, weight: 0.3 },
    ]
}

#[test]
fn peak_live_stays_flat_as_trace_grows_100x() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 64);
    let sim = DesSim::new(&t, DesOpts::default());
    let mut scratch = DesScratch::new();
    let mut run = |n: u64| {
        let mut router = Router::with_seed(&t, 7);
        let src =
            PoissonArrivals::new(7, 100_000.0, n, nics.clone(), mix());
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3)
    };
    let (small, _) = run(1_000);
    let (big, ss) = run(100_000);
    assert_eq!(big.total_nodes, 100_000, "every arrival materializes");
    assert_eq!(ss.completed, 100_000, "every arrival retires");
    assert_eq!(big.late_releases, 0, "arrival floors are never late");
    assert!(
        big.peak_live_nodes <= small.peak_live_nodes * 4,
        "100x arrivals must not grow the live window \
         (peak {} at 100k vs {} at 1k)",
        big.peak_live_nodes,
        small.peak_live_nodes
    );
    let headroom = big.total_nodes as f64 / big.peak_live_nodes as f64;
    assert!(
        headroom >= 50.0,
        "live-node headroom {headroom:.1} below the CI floor \
         (peak {} of {})",
        big.peak_live_nodes,
        big.total_nodes
    );
    // steady-state sanity on the big run
    assert!(ss.duration > 0.0 && ss.throughput_flows > 0.0);
    assert!(ss.p50 > 0.0 && ss.p50 <= ss.p99 && ss.p99 <= ss.p999);
    assert!(ss.peak_inflight >= 1);
    assert_eq!(ss.max_backlog.len(), 2, "one backlog slot per mix class");
    assert!(ss.windows > 0);
}

#[test]
fn short_trace_matches_run_dag_on_materialized_equivalent() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 32);
    // generate a Poisson arrival set, round-trip it through the
    // text trace format (f64 Display is shortest-round-trip, so the
    // parsed times are bit-identical)
    let mut gen = PoissonArrivals::new(21, 5_000.0, 400, nics, mix());
    let mut trace = String::from("# t src dst bytes class\n");
    let mut arrivals: Vec<Arrival> = Vec::new();
    while let Some(a) = gen.next_arrival() {
        trace.push_str(&format!(
            "{} {} {} {} {}\n",
            a.t, a.src, a.dst, a.bytes, a.class
        ));
        arrivals.push(a);
    }
    assert_eq!(arrivals.len(), 400);

    let sim = DesSim::new(&t, DesOpts::default());

    // path A: the trace reader through the streaming open-loop tier
    let mut router_a = Router::with_seed(&t, 99);
    let mut finish = vec![f64::NAN; arrivals.len()];
    let res = {
        let src = TraceArrivals::new(trace.as_bytes());
        let mut ol = OpenLoopSource::new(src, &mut router_a, 1e-3);
        sim.session(&mut DesScratch::new())
            .stream_sink(&mut ol, |id, tf| finish[id as usize] = tf)
    };
    assert_eq!(res.total_nodes, arrivals.len());
    assert_eq!(res.late_releases, 0);

    // path B: the same transfers, routed identically, fully
    // materialized and run closed-loop
    let mut router_b = Router::with_seed(&t, 99);
    let timed: Vec<TimedFlow> = arrivals
        .iter()
        .map(|a| {
            let f = Flow::new(a.src, a.dst, a.bytes);
            TimedFlow {
                rf: RoutedFlow { path: router_b.route(&f), flow: f },
                start: a.t,
            }
        })
        .collect();
    let dag = sim.run_dag(&aurorasim::fabric::DagWorkload::from_timed(&timed));
    assert!((res.makespan - dag.makespan).abs()
        / dag.makespan.abs().max(1e-30)
        < 1e-9);
    for (i, (a, b)) in finish.iter().zip(&dag.node_finish).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(rel < 1e-9, "arrival {i}: stream {a} vs dag {b}");
    }
}

#[test]
fn open_loop_scenario_json_is_identical_across_solver_threads() {
    let scenario = |threads: usize| {
        Scenario::new(
            "ol_det",
            AuroraConfig::small(4, 4),
            DesOpts { solver_threads: threads, ..DesOpts::default() },
            Workload::OpenLoop {
                arrivals: 2_000,
                rate: 50_000.0,
                endpoints: 64,
                mix: mix(),
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 1.0,
                link_fraction: 0.0,
            },
            9,
        )
    };
    let report = |threads: usize, workers: usize| {
        let c = Campaign { scenarios: vec![scenario(threads)] };
        c.run(workers).to_json().dump_pretty()
    };
    let serial = report(1, 1);
    let fanned = report(8, 2);
    assert_eq!(
        serial, fanned,
        "open-loop steady-state report must be byte-identical across \
         DES solver-thread counts"
    );
    assert!(serial.contains("\"p999_s\""));
    assert!(serial.contains("\"peak_live\""));
}

// ------------------------------------------- trace parser diagnostics

/// Drain a trace through the parser and return the panic message it
/// dies with. Builds the reader inside the closure so the unwind can't
/// leave a poisoned source behind.
fn parse_panic(trace: &'static str, bound: Option<u32>) -> String {
    let err = std::panic::catch_unwind(|| {
        let mut src = TraceArrivals::new(trace.as_bytes());
        if let Some(b) = bound {
            src = src.with_endpoint_bound(b);
        }
        while src.next_arrival().is_some() {}
    })
    .expect_err("malformed trace must be rejected");
    err.downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .expect("panic payload is a message")
}

/// Every malformed-trace class dies with a message naming the 1-based
/// physical line (comments and blanks count) and the offending field —
/// a corrupt trace must fail loudly at parse time, never misprice.
#[test]
fn trace_parse_errors_name_the_line_and_field() {
    for (trace, expect) in [
        // truncated record: dst missing
        ("0.5 3\n", "trace line 1: missing dst"),
        // non-numeric bytes field
        ("0.5 3 4 lots\n", "trace line 1: bad bytes"),
        // NaN parses as a valid f64 but is not a valid timestamp
        ("NaN 3 4 1024\n", "trace line 1: non-finite timestamp NaN"),
        // negative start: decreases below the initial floor of 0
        ("-1 3 4 64\n", "trace line 1: timestamp -1 decreases (last 0)"),
        // time travel on the second record
        (
            "1.0 0 1 64\n0.5 0 1 64\n",
            "trace line 2: timestamp 0.5 decreases (last 1)",
        ),
        // self-flow
        ("0.0 5 5 64\n", "trace line 1: src == dst"),
        // line numbers are physical: header + blank push this to line 3
        (
            "# t src dst bytes\n\n2.0 1 1 64\n",
            "trace line 3: src == dst",
        ),
    ] {
        let msg = parse_panic(trace, None);
        assert!(
            msg.contains(expect),
            "trace {trace:?}: expected {expect:?} in panic, got {msg:?}"
        );
    }
}

/// With an endpoint bound installed (the topology's compute-endpoint
/// count), a rank-mangled trace fails at its line instead of panicking
/// deep inside the router.
#[test]
fn trace_endpoint_bound_rejects_out_of_range_ranks() {
    let msg = parse_panic("0.0 7 999 64\n", Some(64));
    assert!(
        msg.contains("trace line 1: dst 999 out of range (endpoints < 64)"),
        "got {msg:?}"
    );
    // in-range ids pass under the same bound
    let mut ok = TraceArrivals::new("0.0 7 63 64\n".as_bytes())
        .with_endpoint_bound(64);
    let a = ok.next_arrival().unwrap();
    assert_eq!((a.src, a.dst, a.bytes), (7, 63, 64));
    assert!(ok.next_arrival().is_none());
}

// ------------------------------------- sparse-window deadlock freedom

/// Arrival gaps thousands of quanta wide must not produce empty
/// rounds: `OpenLoopSource` anchors each round on a real arrival and
/// `next_round_not_before` jumps straight to the next occupied window.
/// (An empty throttled round would spin `materialize_next_round`
/// without advancing time — the exact hazard the workload verifier
/// flags as an `empty-round` error.)
#[test]
fn sparse_arrivals_skip_empty_windows_without_deadlock() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let mut router = Router::with_seed(&t, 5);
    // four arrivals, 1 ms quantum: ~10 000 empty windows between each
    // cluster; the middle two share one window
    let trace = "1e-4 0 1 4096\n10.0 2 3 4096\n10.00005 4 5 4096\n\
                 20.0 6 7 4096\n";
    let src = TraceArrivals::new(trace.as_bytes());
    let mut ol = OpenLoopSource::new(src, &mut router, 1e-3);
    let mut windows = Vec::new();
    let mut nodes = 0usize;
    loop {
        let nb = ol.next_round_not_before();
        let Some(round) = ol.next_round() else { break };
        assert!(!round.is_empty(), "rounds anchor on a real arrival");
        for n in &round {
            let start = match n {
                StreamNode::Compute { start, .. }
                | StreamNode::Xfer { start, .. } => *start,
            };
            assert!(
                start >= nb,
                "floor {start} precedes its announced window {nb}"
            );
        }
        windows.push(nb);
        nodes += round.len();
    }
    assert_eq!(nodes, 4, "every arrival materializes exactly once");
    assert_eq!(
        windows,
        vec![0.0, 10.0, 20.0],
        "not-before jumps occupied window to occupied window"
    );
    assert_eq!(
        ol.next_round_not_before(),
        0.0,
        "exhausted source reports no deferral"
    );

    // end-to-end: the same sparse trace runs through the streaming
    // executor with zero late releases
    let mut router = Router::with_seed(&t, 5);
    let src = TraceArrivals::new(trace.as_bytes()).with_endpoint_bound(64);
    let sim = DesSim::new(&t, DesOpts::default());
    let mut ol = OpenLoopSource::new(src, &mut router, 1e-3);
    let res = sim.session(&mut DesScratch::new()).stream(&mut ol);
    assert_eq!(res.total_nodes, 4);
    assert_eq!(res.late_releases, 0, "sparse windows never release late");
    assert!(res.makespan > 20.0, "the final arrival at t=20 s completes");
}

// ---------------------------------------- PR-10 graceful degradation

/// Satellite-1 regression: flows failed by the fault policy retire
/// through the collector at their failure instant. Eight 8 MiB incast
/// flows onto a NIC that dies at t = 100 us exhaust their retry
/// backoff and fail; the latency quantiles stay clean (only the fast
/// bystanders and late probes enter the histogram), and — the phantom
/// -backlog bug this pins — probe arrivals 50 ms later are admitted
/// under a backlog-threshold policy, which only holds if the failures
/// really left the class-0 backlog.
#[test]
fn fault_failed_flows_retire_and_keep_quantiles_clean() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let eps = workload::spread_nics(&t, 16);
    let dead = eps[1];
    let mut trace = String::from("# t src dst bytes class\n");
    for i in 0..8 {
        // class-0 incast onto the doomed NIC: still in flight at the
        // fault (8 flows sharing one ejection NIC need milliseconds)
        trace.push_str(&format!("0 {} {dead} 8388608 0\n", eps[2 + i]));
    }
    for i in 0..4 {
        // class-1 bystanders between healthy endpoints: microseconds
        trace.push_str(&format!(
            "0 {} {} 65536 1\n",
            eps[10 + i],
            eps[10 + (i + 1) % 4]
        ));
    }
    for _ in 0..6 {
        // late class-0 probes, long after the failures resolved
        trace.push_str(&format!("0.05 {} {} 65536 0\n", eps[2], eps[3]));
    }
    let faults = FaultSchedule::new(FaultPolicy::RetryBackoff {
        timeout: 25e-6,
        backoff: 2.0,
        max_retries: 2,
    })
    .at(100e-6, FaultKind::NicDown { endpoint: dead });
    let run = |policies: Option<ServicePolicy>| {
        let sim = DesSim::new(
            &t,
            DesOpts {
                faults: Some(faults.clone()),
                policies,
                ..DesOpts::default()
            },
        );
        let mut scratch = DesScratch::new();
        let mut router = Router::with_seed(&t, 3);
        let src = TraceArrivals::new(trace.as_bytes());
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3)
    };

    let (res, ss) = run(None);
    assert_eq!(res.failed_flows, 8, "every incast flow fails");
    assert_eq!(ss.arrivals, 18, "no policy: every arrival is accepted");
    assert_eq!(ss.completed, 10, "bystanders and probes complete");
    assert_eq!(ss.failed.first().copied(), Some(8));
    assert_eq!(ss.failed.iter().sum::<u64>(), 8);
    assert!(ss.p50 > 0.0 && ss.p999.is_finite());
    assert!(
        ss.p999 < 1e-3,
        "failed incasts must never enter the histogram (p999 {})",
        ss.p999
    );
    let (_, ss2) = run(None);
    assert_eq!(ss, ss2, "failure accounting is deterministic");

    // phantom-backlog regression: with a class backlog threshold of 8,
    // the 6 probes at t = 50 ms are admitted only because the 8 failed
    // incasts retired from the backlog at their failure instant
    let probe = ServicePolicy::uniform(
        2,
        ClassPolicy { backlog_limit: 8, ..ClassPolicy::OFF },
    );
    let (res_p, ss_p) = run(Some(probe));
    assert_eq!(res_p.failed_flows, 8);
    assert_eq!(
        ss_p.shed.iter().sum::<u64>(),
        0,
        "failed flows must leave the backlog — probes were shed"
    );
    assert_eq!(ss_p.completed, 10);
}

/// An armed-but-inert [`ServicePolicy`] (every control off) must be
/// bit-identical to running with no policy at all — the degradation
/// path may tag and check but never perturb (the test-scale twin of
/// the gated `degrade_overhead` bench's in-bench equality assertion).
#[test]
fn inert_policy_is_bit_identical_to_no_policy() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let nics = workload::spread_nics(&t, 64);
    let run = |policies: Option<ServicePolicy>| {
        let sim = DesSim::new(&t, DesOpts { policies, ..DesOpts::default() });
        let mut scratch = DesScratch::new();
        let mut router = Router::with_seed(&t, 13);
        let src =
            PoissonArrivals::new(13, 80_000.0, 5_000, nics.clone(), mix());
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3)
    };
    let (rn, sn) = run(None);
    let (ri, si) = run(Some(ServicePolicy::uniform(2, ClassPolicy::OFF)));
    assert_eq!(sn, si, "inert policy must not move steady-state metrics");
    assert_eq!(rn.makespan.to_bits(), ri.makespan.to_bits());
    assert_eq!(rn.peak_live_nodes, ri.peak_live_nodes);
    assert_eq!(ri.abandoned_flows + ri.hedged_flows, 0);
    assert_eq!(sn.completed, 5_000);
}

/// The brownout scenario (mid-run flaps + armed policy) serializes to
/// byte-identical JSON at every DES solver-thread count — EV_DEADLINE
/// and EV_HEDGE ride the same deterministic event heap as everything
/// else, and the v5 degradation block is a pure function of the run.
#[test]
fn brownout_scenario_json_is_identical_across_solver_threads() {
    let topo = Topology::new(&AuroraConfig::small(4, 4));
    let faults = FaultSchedule::random_flaps(
        &topo,
        4,
        0.04,
        4e-3,
        11,
        FaultPolicy::RetryBackoff {
            timeout: 25e-6,
            backoff: 2.0,
            max_retries: 6,
        },
    );
    let scenario = |threads: usize| {
        Scenario::new(
            "brownout_det",
            AuroraConfig::small(4, 4),
            DesOpts {
                solver_threads: threads,
                faults: Some(faults.clone()),
                policies: Some(brownout_policy(&mix(), 96, 12e-3, 400.0)),
                ..DesOpts::default()
            },
            Workload::OpenLoop {
                arrivals: 3_000,
                rate: 60_000.0,
                endpoints: 64,
                mix: mix(),
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 1.0,
                link_fraction: 0.0,
            },
            9,
        )
    };
    let report = |threads: usize, workers: usize| {
        let c = Campaign { scenarios: vec![scenario(threads)] };
        c.run(workers).to_json().dump_pretty()
    };
    let serial = report(1, 1);
    let fanned = report(8, 2);
    assert_eq!(
        serial, fanned,
        "brownout report must be byte-identical across DES solver threads"
    );
    assert!(serial.contains("\"degradation\""));
    assert!(serial.contains("\"goodput_flows_per_s\""));
    assert!(serial.contains("\"shed\""));
}

/// ISSUE-10 acceptance: under a mid-run brownout (the incast NIC
/// degrades to 10% capacity while offered load stays fixed), the
/// unprotected run's backlog grows with offered load, while a
/// backlog-threshold policy caps the backlog at its limit and a
/// deadline policy keeps the accepted p99 within 2x the healthy p99 —
/// structurally, since EV_DEADLINE abandons any request the instant
/// its SLO expires.
#[test]
fn brownout_policy_keeps_latency_bounded_and_backlog_flat_under_flap() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let eps = workload::spread_nics(&t, 16);
    // 100k arrivals/s of 64 KiB onto one ejection NIC: rho ~ 0.3
    // healthy (22.5 GB/s NIC), rho ~ 3 after the 0.1x degrade
    let trace = |n: usize| {
        let mut s = String::new();
        for i in 0..n {
            s.push_str(&format!(
                "{} {} {} 65536 0\n",
                i as f64 * 1e-5,
                eps[1 + (i % 12)],
                eps[0]
            ));
        }
        s
    };
    let run = |n: usize, fault: bool, policies: Option<ServicePolicy>| {
        let faults = if fault {
            Some(FaultSchedule::new(FaultPolicy::Reroute).at(
                2e-3,
                FaultKind::LinkDegrade {
                    link: LinkId::NicDown(eps[0]),
                    multiplier: 0.1,
                },
            ))
        } else {
            None
        };
        let sim =
            DesSim::new(&t, DesOpts { faults, policies, ..DesOpts::default() });
        let mut scratch = DesScratch::new();
        let mut router = Router::with_seed(&t, 17);
        let tr = trace(n);
        let src = TraceArrivals::new(tr.as_bytes());
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3)
    };

    let (_, hs) = run(2000, false, None);
    assert_eq!(hs.completed, 2000);
    assert!(hs.p99 > 0.0 && hs.p99.is_finite());

    // policy-off: backlog grows monotonically with offered load
    let (_, so_small) = run(1000, true, None);
    let (_, so_big) = run(2000, true, None);
    assert!(
        so_big.max_backlog[0] > so_small.max_backlog[0],
        "unprotected backlog must grow with offered load ({} vs {})",
        so_big.max_backlog[0],
        so_small.max_backlog[0]
    );

    // shed-only policy: the backlog threshold caps the queue and sheds
    // the overload the unprotected run absorbs
    let shed_policy = ServicePolicy::uniform(
        1,
        ClassPolicy { backlog_limit: 64, ..ClassPolicy::OFF },
    );
    let (_, son) = run(2000, true, Some(shed_policy));
    assert!(
        son.max_backlog[0] <= 64,
        "backlog must stay at the limit (max {})",
        son.max_backlog[0]
    );
    assert!(
        so_big.max_backlog[0] >= 4 * son.max_backlog[0],
        "policy-off backlog ({}) must dwarf the capped one ({})",
        so_big.max_backlog[0],
        son.max_backlog[0]
    );
    assert!(son.shed.iter().sum::<u64>() > 0, "overload must shed");
    let retired = son.completed
        + son.abandoned.iter().sum::<u64>()
        + son.failed.iter().sum::<u64>();
    assert_eq!(retired, son.arrivals, "every accepted request retires");
    assert_eq!(
        son.arrivals + son.shed.iter().sum::<u64>(),
        2000,
        "accepted + shed covers the offered load"
    );

    // deadline policy: accepted p99 bounded by the SLO, backlog flat
    let dl_policy = ServicePolicy::uniform(
        1,
        ClassPolicy { deadline: hs.p99 * 1.8, ..ClassPolicy::OFF },
    );
    let (_, sdl) = run(2000, true, Some(dl_policy));
    assert!(
        sdl.p99 <= hs.p99 * 2.0,
        "deadline policy must keep accepted p99 ({}) within 2x healthy ({})",
        sdl.p99,
        hs.p99
    );
    assert!(
        so_big.p99 >= hs.p99 * 2.0,
        "unprotected p99 ({}) must blow past 2x healthy ({})",
        so_big.p99,
        hs.p99
    );
    assert!(
        sdl.max_backlog[0] * 4 <= so_big.max_backlog[0],
        "abandonment keeps the backlog flat ({} vs {})",
        sdl.max_backlog[0],
        so_big.max_backlog[0]
    );
    assert!(sdl.abandoned.iter().sum::<u64>() > 0, "overload must abandon");
    assert!(sdl.completed > 0 && sdl.goodput_flows > 0.0);
    assert_eq!(sdl.deadline_met, sdl.completed, "every completion met its SLO");
}

/// Hedged requests duplicate onto a link-disjoint minimal route after
/// `hedge_delay` and the first completion wins. The primary's non-NIC
/// links are statically degraded to 1e-3x, so the primary alone would
/// take ~46 ms; the hedge twin on the disjoint candidate finishes in
/// microseconds and cancels it.
#[test]
fn hedge_duplicates_onto_disjoint_route_and_first_completion_wins() {
    let t = Topology::new(&AuroraConfig::small(4, 4));
    let eps = workload::spread_nics(&t, 4);
    let (s, d) = (eps[0], eps[1]);
    // probe: an identically seeded router replays the real router's
    // first (and only) route decision
    let primary = Router::with_seed(&t, 31).route(&Flow::new(s, d, 1 << 20));
    assert!(primary.minimal, "zero load routes minimally");
    let slow: Vec<LinkId> = primary
        .links
        .iter()
        .copied()
        .filter(|l| !matches!(l, LinkId::NicUp(_) | LinkId::NicDown(_)))
        .collect();
    assert!(!slow.is_empty(), "cross-group path has switch links");

    let mut opts = DesOpts::default();
    for l in &slow {
        opts.degraded.insert(*l, 1e-3);
    }
    opts.policies = Some(ServicePolicy::uniform(
        1,
        ClassPolicy { hedge_delay: 50e-6, ..ClassPolicy::OFF },
    ));
    let sim = DesSim::new(&t, opts);
    let mut scratch = DesScratch::new();
    let mut router = Router::with_seed(&t, 31);
    let trace = format!("0 {s} {d} 1048576 0\n");
    let src = TraceArrivals::new(trace.as_bytes());
    let (res, ss) =
        run_open_loop(&sim, &mut scratch, src, &mut router, 1e-3, 10e-3);
    assert_eq!(res.hedged_flows, 1, "the crawling primary hedges");
    assert_eq!(ss.hedged.iter().sum::<u64>(), 1);
    assert_eq!(ss.completed, 1, "first completion wins, exactly once");
    assert_eq!(res.failed_flows, 0);
    assert!(
        res.makespan < 5e-3,
        "the disjoint hedge route must finish in microseconds, not the \
         primary's ~46 ms crawl (makespan {})",
        res.makespan
    );
}
