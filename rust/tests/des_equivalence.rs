//! Incremental-vs-oracle DES equivalence sweep + campaign determinism.
//!
//! The incremental solver (`DesSim::run`) re-solves only the component of
//! flows affected by each event; the oracle (`DesSim::run_oracle`)
//! re-solves the whole dense system. Both converge to the same unique
//! max-min fixpoint, so per-flow finish times must agree to floating-
//! point noise. This suite sweeps >= 50 seeded mixed workloads (uniform,
//! incast, degraded links, staggered arrivals, congestion management
//! on/off) asserting 1e-9 relative agreement, and checks that the
//! campaign engine's parallel execution is byte-identical to serial.
//!
//! Closed-loop extension (EXPERIMENTS.md §Closed-loop): the same
//! contract for dependency-released workloads — `DesSim::run_dag`
//! (incremental, event-heap-integrated releases) against
//! `DesSim::run_dag_oracle` (full re-solve per event) over ring-round
//! DAGs with congestors, incast interference, degraded links and the
//! HACC / AMR-Wind / LAMMPS step traces — plus the open-loop
//! degeneration (`DagWorkload::from_timed` reproduces `run`).
//!
//! Fault-timeline extension (EXPERIMENTS.md §Fault injection): a t=0
//! `FaultSchedule` must price bit-identically to static
//! `DesOpts::degraded` at every solver thread count, and a fault event
//! sharing a timestamp with a flow completion resolves deterministically
//! (the fault sweep runs first but never fails flows that complete in
//! the same batch).

use aurorasim::campaign::{Campaign, Scenario, Workload};
use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{DesOpts, DesSim, TimedFlow};
use aurorasim::fabric::workload::{self, DagWorkload};
use aurorasim::fabric::{Flow, RoutedFlow, Router};
use aurorasim::topology::Topology;
use aurorasim::util::Pcg;
use std::collections::BTreeMap;

const REL_TOL: f64 = 1e-9;

/// Closed-loop analogue of [`assert_equivalent`]: the incremental
/// dependency-DAG solver against the full-re-solve oracle.
fn assert_dag_equivalent(
    topo: &Topology,
    opts: &DesOpts,
    wl: &DagWorkload,
    what: &str,
) {
    let sim = DesSim::new(topo, opts.clone());
    let inc = sim.run_dag(wl);
    let ora = sim.run_dag_oracle(wl);
    assert_eq!(inc.node_finish.len(), ora.node_finish.len(), "{what}");
    for (i, (a, b)) in
        inc.node_finish.iter().zip(&ora.node_finish).enumerate()
    {
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(
            rel < REL_TOL,
            "{what} node {i}: incremental {a:.15e} vs oracle {b:.15e} \
             (rel {rel:.2e})"
        );
    }
    assert_eq!(inc.contributors, ora.contributors, "{what}: contributors");
    assert_eq!(inc.victims, ora.victims, "{what}: victims");
    let rel = (inc.makespan - ora.makespan).abs() / ora.makespan.max(1e-30);
    assert!(rel < REL_TOL, "{what}: makespan rel {rel:.2e}");
}

fn assert_equivalent(
    topo: &Topology,
    opts: &DesOpts,
    timed: &[TimedFlow],
    what: &str,
) {
    let sim = DesSim::new(topo, opts.clone());
    let inc = sim.run(timed);
    let ora = sim.run_oracle(timed);
    assert_eq!(inc.finish.len(), ora.finish.len(), "{what}");
    for (i, (a, b)) in inc.finish.iter().zip(&ora.finish).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-30);
        assert!(
            rel < REL_TOL,
            "{what} flow {i}: incremental {a:.15e} vs oracle {b:.15e} \
             (rel {rel:.2e})"
        );
    }
    assert_eq!(inc.contributors, ora.contributors, "{what}: contributors");
    assert_eq!(inc.victims, ora.victims, "{what}: victims");
    let rel = (inc.makespan - ora.makespan).abs() / ora.makespan.max(1e-30);
    assert!(rel < REL_TOL, "{what}: makespan rel {rel:.2e}");
}

/// One randomized mixed case: uniform background + an incast clique +
/// optionally degraded links and staggered arrivals.
fn mixed_case(
    topo: &Topology,
    rng: &mut Pcg,
    n_uniform: usize,
    incast_fanin: usize,
    degrade: bool,
    stagger: bool,
) -> (Vec<TimedFlow>, DesOpts) {
    let nics = topo.cfg.compute_endpoints() as u64;
    let mut router = Router::with_seed(topo, rng.next_u64());
    let mut timed: Vec<TimedFlow> = Vec::new();
    let push = |router: &mut Router, f: Flow, start: f64,
                timed: &mut Vec<TimedFlow>| {
        let path = router.route(&f);
        timed.push(TimedFlow { rf: RoutedFlow { path, flow: f }, start });
    };
    for i in 0..n_uniform {
        let src = rng.gen_range(nics) as u32;
        let dst = ((src as u64 + 1 + rng.gen_range(nics - 1)) % nics) as u32;
        let bytes = 1 + rng.gen_range(4 << 20);
        let start = if stagger {
            // millisecond-granular so arrival batching is well defined
            (i % 5) as f64 * 1e-3
        } else {
            0.0
        };
        push(&mut router, Flow::new(src, dst, bytes), start, &mut timed);
    }
    if incast_fanin > 0 {
        let root = rng.gen_range(nics) as u32;
        for _ in 0..incast_fanin {
            let mut src = rng.gen_range(nics) as u32;
            if src == root {
                src = (src + 9) % nics as u32;
            }
            let bytes = 1 + rng.gen_range(8 << 20);
            push(&mut router, Flow::new(src, root, bytes), 0.0, &mut timed);
        }
    }
    let mut opts = DesOpts::default();
    if degrade {
        let mut degraded = BTreeMap::new();
        for tf in timed.iter().step_by(3) {
            for l in &tf.rf.path.links {
                degraded.insert(*l, 0.25 + 0.5 * rng.gen_f64());
            }
        }
        opts.degraded = degraded;
    }
    (timed, opts)
}

#[test]
fn sweep_uniform_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE01);
    for case in 0..14 {
        let (timed, opts) = mixed_case(&topo, &mut rng, 24, 0, false, false);
        assert_equivalent(&topo, &opts, &timed, &format!("uniform {case}"));
    }
}

#[test]
fn sweep_incast_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE02);
    for case in 0..14 {
        let fanin = 4 + rng.gen_usize(12);
        let (timed, mut opts) =
            mixed_case(&topo, &mut rng, 12, fanin, false, false);
        // alternate congestion management to cover the victim path
        opts.congestion_mgmt = case % 2 == 0;
        assert_equivalent(
            &topo,
            &opts,
            &timed,
            &format!("incast {case} fanin {fanin} cm {}",
                opts.congestion_mgmt),
        );
    }
}

#[test]
fn sweep_degraded_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE03);
    for case in 0..12 {
        let (timed, opts) = mixed_case(&topo, &mut rng, 20, 6, true, false);
        assert_equivalent(&topo, &opts, &timed, &format!("degraded {case}"));
    }
}

#[test]
fn sweep_staggered_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE04);
    for case in 0..12 {
        let (timed, mut opts) =
            mixed_case(&topo, &mut rng, 20, 5, case % 3 == 0, true);
        opts.congestion_mgmt = case % 2 == 1;
        assert_equivalent(&topo, &opts, &timed, &format!("staggered {case}"));
    }
}

#[test]
fn empty_and_single_flow() {
    let topo = Topology::new(&AuroraConfig::small(4, 4));
    let sim = DesSim::new(&topo, DesOpts::default());
    assert!(sim.run(&[]).finish.is_empty());
    let mut router = Router::new(&topo);
    let f = Flow::new(0, 200, 1 << 20);
    let timed = vec![TimedFlow {
        rf: RoutedFlow { path: router.route(&f), flow: f },
        start: 0.5,
    }];
    assert_equivalent(&topo, &DesOpts::default(), &timed, "single flow");
}

// ------------------------------------------------------------- closed loop

/// One randomized closed-loop case: dependency-released ring rounds plus
/// open-loop congestors (uniform + an incast clique), optionally over
/// degraded links.
fn closed_loop_case(
    topo: &Topology,
    rng: &mut Pcg,
    ranks: usize,
    rounds: usize,
    congestors: usize,
    incast_fanin: usize,
    degrade: bool,
) -> (DagWorkload, DesOpts) {
    let nics_total = topo.cfg.compute_endpoints() as u64;
    let mut router = Router::with_seed(topo, rng.next_u64());
    let nics = workload::spread_nics(topo, ranks);
    let rr = workload::ring_rounds(&nics, rounds, 1 + rng.gen_range(2 << 20));
    let mut wl = workload::dag_from_rounds(&mut router, &rr, 0.0);
    for i in 0..congestors {
        let src = rng.gen_range(nics_total) as u32;
        let dst =
            ((src as u64 + 1 + rng.gen_range(nics_total - 1)) % nics_total)
                as u32;
        let f = Flow::new(src, dst, 1 + rng.gen_range(4 << 20));
        let path = router.route(&f);
        wl.xfer_at(
            RoutedFlow { flow: f, path },
            (i % 3) as f64 * 1e-3,
        );
    }
    if incast_fanin > 0 {
        let root = rng.gen_range(nics_total) as u32;
        for _ in 0..incast_fanin {
            let mut src = rng.gen_range(nics_total) as u32;
            if src == root {
                src = (src + 9) % nics_total as u32;
            }
            let f = Flow::new(src, root, 1 + rng.gen_range(8 << 20));
            let path = router.route(&f);
            wl.xfer_at(RoutedFlow { flow: f, path }, 0.0);
        }
    }
    let mut opts = DesOpts::default();
    if degrade {
        let mut degraded = BTreeMap::new();
        for node in wl.nodes.iter().step_by(3) {
            if let aurorasim::fabric::DagKind::Xfer(rf) = &node.kind {
                for l in &rf.path.links {
                    degraded.insert(*l, 0.25 + 0.5 * rng.gen_f64());
                }
            }
        }
        opts.degraded = degraded;
    }
    (wl, opts)
}

#[test]
fn sweep_closed_loop_ring_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE05);
    for case in 0..10 {
        let ranks = 8 + rng.gen_usize(12);
        let rounds = 3 + rng.gen_usize(6);
        let (wl, opts) =
            closed_loop_case(&topo, &mut rng, ranks, rounds, 8, 0, false);
        assert_dag_equivalent(
            &topo,
            &opts,
            &wl,
            &format!("closed ring {case} ({ranks}x{rounds})"),
        );
    }
}

#[test]
fn sweep_closed_loop_incast_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE06);
    for case in 0..10 {
        let fanin = 4 + rng.gen_usize(10);
        let (wl, mut opts) =
            closed_loop_case(&topo, &mut rng, 10, 4, 4, fanin, false);
        opts.congestion_mgmt = case % 2 == 0;
        assert_dag_equivalent(
            &topo,
            &opts,
            &wl,
            &format!("closed incast {case} fanin {fanin} cm {}",
                opts.congestion_mgmt),
        );
    }
}

#[test]
fn sweep_closed_loop_degraded_cases() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE07);
    for case in 0..8 {
        let (wl, mut opts) =
            closed_loop_case(&topo, &mut rng, 12, 5, 6, 5, true);
        opts.congestion_mgmt = case % 2 == 1;
        assert_dag_equivalent(&topo, &opts, &wl, &format!("closed deg {case}"));
    }
}

#[test]
fn closed_loop_app_steps_match_oracle() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut r1 = Router::with_seed(&topo, 21);
    let hacc = aurorasim::apps::hacc::step_dag(&topo, &mut r1, 12, 4 << 20);
    assert_dag_equivalent(&topo, &DesOpts::default(), &hacc, "hacc step");
    let mut r2 = Router::with_seed(&topo, 22);
    let amr =
        aurorasim::apps::amr_wind::step_dag(&topo, &mut r2, 12, 1 << 20);
    assert_dag_equivalent(&topo, &DesOpts::default(), &amr, "amr-wind step");
    let mut r3 = Router::with_seed(&topo, 23);
    let lammps =
        aurorasim::apps::lammps::step_dag(&topo, &mut r3, 12, 4 << 20);
    assert_dag_equivalent(&topo, &DesOpts::default(), &lammps, "lammps step");
}

#[test]
fn open_loop_dag_matches_timed_run() {
    // the DAG runner with no dependencies must agree with the original
    // open-loop solver on the same flow set
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE08);
    for case in 0..6 {
        let (timed, opts) = mixed_case(&topo, &mut rng, 18, 6, false, true);
        let wl = DagWorkload::from_timed(&timed);
        let open = DesSim::new(&topo, opts.clone()).run(&timed);
        let dag = DesSim::new(&topo, opts).run_dag(&wl);
        for (i, (a, b)) in
            open.finish.iter().zip(&dag.node_finish).enumerate()
        {
            let rel = (a - b).abs() / b.abs().max(1e-30);
            assert!(rel < REL_TOL, "case {case} flow {i}: {a} vs {b}");
        }
    }
}

// ---------------------------------------------------------------- streaming

/// Stream and materialize the same routed round structure (independent
/// same-seeded routers produce identical flows) and assert the windowed
/// streaming executor reproduces the fully materialized closed-loop run.
fn assert_stream_equivalent(
    topo: &Topology,
    opts: &DesOpts,
    rounds: &[Vec<(u32, u32, u64)>],
    seed: u64,
    what: &str,
) {
    let mut r1 = Router::with_seed(topo, seed);
    let dag = workload::dag_from_rounds(&mut r1, rounds, 0.0);
    let sim = DesSim::new(topo, opts.clone());
    let full = sim.run_dag(&dag);
    let mut r2 = Router::with_seed(topo, seed);
    let rv = rounds.to_vec();
    let mut src =
        workload::routed_round_source(&mut r2, move |k| rv.get(k).cloned());
    let streamed = sim.run_stream(&mut src);
    assert_eq!(streamed.late_releases, 0, "{what}: late releases");
    assert_eq!(streamed.total_nodes, dag.len(), "{what}: node count");
    assert_eq!(
        streamed.contributors, full.contributors,
        "{what}: contributors"
    );
    assert_eq!(streamed.victims, full.victims, "{what}: victims");
    let rel = (streamed.makespan - full.makespan).abs()
        / full.makespan.max(1e-30);
    assert!(
        rel < REL_TOL,
        "{what}: streamed {:.15e} vs materialized {:.15e} (rel {rel:.2e})",
        streamed.makespan,
        full.makespan
    );
}

#[test]
fn sweep_streaming_matches_materialized() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE09);
    for case in 0..9 {
        let ranks = 6 + rng.gen_usize(10);
        let nics = workload::spread_nics(&topo, ranks);
        let bytes = 1 + rng.gen_range(2 << 20);
        let rounds = match case % 3 {
            0 => workload::ring_rounds(&nics, 3 + rng.gen_usize(5), bytes),
            1 => workload::pairwise_rounds(&nics, bytes),
            _ => workload::doubling_rounds(&nics, bytes),
        };
        let opts = DesOpts {
            congestion_mgmt: case % 2 == 0,
            ..DesOpts::default()
        };
        assert_stream_equivalent(
            &topo,
            &opts,
            &rounds,
            rng.next_u64(),
            &format!("stream {case} ({ranks} ranks)"),
        );
    }
}

#[test]
fn streaming_executor_reaches_fig14_scale() {
    // the Fig 14 headline scale: 2,048 simulated endpoints, closed-loop.
    // The windowed executor must keep only a dependency-skew window of
    // rounds live — peak live nodes far below rounds x P — where full
    // materialization holds every routed flow at once.
    let topo = Topology::new(&AuroraConfig::small(16, 16)); // 4,096 NICs
    let p = 2048usize;
    let nics = workload::spread_nics(&topo, p);
    let sim = DesSim::new(&topo, DesOpts::default());

    // ring allreduce rounds (the large-message regime of Fig 14; equal
    // 1 MiB chunks keep per-endpoint round times near-identical, so the
    // dependency skew — and with it the live window — stays small)
    let ring_rounds = 12usize;
    let ring = workload::ring_rounds(&nics, ring_rounds, 1 << 20);
    let mut r1 = Router::with_seed(&topo, 41);
    let rv = ring.clone();
    let mut src =
        workload::routed_round_source(&mut r1, move |k| rv.get(k).cloned());
    let res = sim.run_stream(&mut src);
    assert_eq!(res.total_nodes, ring_rounds * p);
    assert_eq!(res.late_releases, 0);
    assert!(res.makespan > 0.0 && res.makespan.is_finite());
    assert!(
        res.peak_live_nodes * 2 < res.total_nodes,
        "ring: peak live {} must be << total {}",
        res.peak_live_nodes,
        res.total_nodes
    );

    // pairwise all2all rotation rounds (first shifts of the P-1 sweep),
    // generated lazily — the O(P^2) triple list never materializes
    let shifts = 8usize;
    let mut r2 = Router::with_seed(&topo, 42);
    let nics2 = nics.clone();
    let mut src2 = workload::routed_round_source(&mut r2, move |k| {
        if k >= shifts {
            return None;
        }
        Some(
            (0..p)
                .map(|i| (nics2[i], nics2[(i + k + 1) % p], 1 << 20))
                .collect(),
        )
    });
    let res2 = sim.run_stream(&mut src2);
    assert_eq!(res2.total_nodes, shifts * p);
    assert_eq!(res2.late_releases, 0);
    assert!(res2.makespan > 0.0 && res2.makespan.is_finite());
    assert!(
        res2.peak_live_nodes * 2 < res2.total_nodes,
        "pairwise: peak live {} must be << total {}",
        res2.peak_live_nodes,
        res2.total_nodes
    );
}

#[test]
fn des_world_full_collective_coverage_and_supersteps() {
    use aurorasim::machine::Machine;
    use aurorasim::mpi::{coll, Comm, World};
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let comm = Comm::world(12);
    // bcast / allgather / reduce_scatter price closed-loop on a
    // des_fabric() world: positive makespans, clocks synced
    let mut w = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
    let tb = coll::bcast(&mut w, &comm, 0, 1 << 20);
    let tg = coll::allgather(&mut w, &comm, 1 << 20);
    let tr = coll::reduce_scatter(&mut w, &comm, 12 << 20);
    for (t, what) in
        [(tb, "bcast"), (tg, "allgather"), (tr, "reduce_scatter")]
    {
        assert!(t > 0.0 && t.is_finite(), "{what}: {t}");
    }
    let t0 = w.clock[0];
    assert!(t0 > 0.0);
    assert!(w.clock.iter().all(|&c| (c - t0).abs() < 1e-12));

    // World::exchange supersteps: two dependency-chained rounds must
    // take clearly longer than the first round alone
    let mut w1 = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
    w1.exchange(&[(0, 6, 8 << 20)]);
    let single = w1.elapsed();
    assert!(single > 0.0);
    let mut w2 = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
    w2.begin_superstep();
    w2.exchange(&[(0, 6, 8 << 20)]);
    w2.exchange(&[(6, 0, 8 << 20)]);
    let span = w2.end_superstep();
    assert!(span > single * 1.5, "span {span} vs single {single}");
    assert!((w2.elapsed() - span).abs() < 1e-12);
}

// ------------------------------------------------------------- route cache

/// Cached-vs-uncached equivalence: intra-group endpoint sets have
/// exactly one minimal candidate per pair and the adaptive decision
/// short-circuits before any load comparison, so the cached and the
/// uncached router provably choose identical paths round after round —
/// the two runs must be byte-identical (paths equal, `DagResult` and
/// `StreamResult` within solver fp noise).
#[test]
fn route_cache_cached_matches_uncached_on_repeated_rounds() {
    use aurorasim::fabric::DagKind;
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    // 12 endpoints inside group 0 (64 compute endpoints per group)
    let nics: Vec<u32> = (0..12u32).map(|i| i * 5).collect();
    let patterns: Vec<(&str, Vec<Vec<(u32, u32, u64)>>)> = vec![
        ("ring", workload::ring_rounds(&nics, 6, 1 << 20)),
        (
            "halo",
            (0..5)
                .map(|_| workload::neighbor_round(&nics, &[-1, 1], 512 << 10))
                .collect(),
        ),
    ];
    for (what, rounds) in patterns {
        let mut plain = Router::with_seed(&topo, 77);
        let dag_plain = workload::dag_from_rounds(&mut plain, &rounds, 0.0);
        let mut cached = Router::with_seed(&topo, 77);
        cached.enable_route_cache();
        let dag_cached = workload::dag_from_rounds(&mut cached, &rounds, 0.0);
        assert!(cached.route_cache_hits() > 0, "{what}: cache must engage");
        assert_eq!(dag_plain.len(), dag_cached.len(), "{what}");
        for (a, b) in dag_plain.nodes.iter().zip(&dag_cached.nodes) {
            match (&a.kind, &b.kind) {
                (DagKind::Xfer(x), DagKind::Xfer(y)) => {
                    assert_eq!(x.path, y.path, "{what}: paths must match");
                }
                _ => panic!("{what}: kind mismatch"),
            }
        }
        let sim = DesSim::new(&topo, DesOpts::default());
        let rp = sim.run_dag(&dag_plain);
        let rc = sim.run_dag(&dag_cached);
        for (i, (x, y)) in
            rp.node_finish.iter().zip(&rc.node_finish).enumerate()
        {
            let rel = (x - y).abs() / y.abs().max(1e-30);
            assert!(rel < REL_TOL, "{what} node {i}: {x} vs {y}");
        }
        assert_eq!(rp.contributors, rc.contributors, "{what}");
        assert_eq!(rp.victims, rc.victims, "{what}");
        // and the streamed executor prices the cached routes identically
        let mut r3 = Router::with_seed(&topo, 77);
        r3.enable_route_cache();
        let rv = rounds.clone();
        let mut src = workload::routed_round_source(&mut r3, move |k| {
            rv.get(k).cloned()
        });
        let streamed = sim.run_stream(&mut src);
        assert_eq!(streamed.late_releases, 0, "{what}");
        let rel = (streamed.makespan - rc.makespan).abs()
            / rc.makespan.max(1e-30);
        assert!(rel < REL_TOL, "{what}: streamed vs cached dag");
    }
}

/// Route-cache invalidation: the cache memoizes *paths* only, so a
/// degraded-fabric run right after a clean run on the same cached
/// router reprices every flow against its own `DesOpts` — no stale
/// cached capacities — and matches the uncached degraded run exactly.
#[test]
fn route_cache_does_not_leak_capacities_across_des_opts() {
    use aurorasim::fabric::DagKind;
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let nics: Vec<u32> = (0..10u32).map(|i| i * 6).collect();
    let rounds = workload::ring_rounds(&nics, 5, 2 << 20);
    let mut cached = Router::with_seed(&topo, 9);
    cached.enable_route_cache();
    let dag = workload::dag_from_rounds(&mut cached, &rounds, 0.0);
    assert!(cached.route_cache_hits() > 0);
    let clean = DesSim::new(&topo, DesOpts::default()).run_dag(&dag);
    // degrade every used link to 25% and reprice the SAME cached routes
    let mut degraded = BTreeMap::new();
    for node in &dag.nodes {
        if let DagKind::Xfer(rf) = &node.kind {
            for l in &rf.path.links {
                degraded.insert(*l, 0.25);
            }
        }
    }
    let opts = DesOpts { degraded, ..DesOpts::default() };
    let slow = DesSim::new(&topo, opts.clone()).run_dag(&dag);
    assert!(
        slow.makespan > clean.makespan * 1.5,
        "degraded run after a clean run must reprice: {} vs {}",
        slow.makespan,
        clean.makespan
    );
    // identical to an uncached degraded run (same paths intra-group)
    let mut plain = Router::with_seed(&topo, 9);
    let dag2 = workload::dag_from_rounds(&mut plain, &rounds, 0.0);
    let slow2 = DesSim::new(&topo, opts).run_dag(&dag2);
    let rel = (slow.makespan - slow2.makespan).abs()
        / slow2.makespan.max(1e-30);
    assert!(rel < REL_TOL, "cached vs uncached degraded repricing");
}

/// Closed-loop campaign scenarios now route through a cached router
/// (`Scenario::materialize_dag`). The cache must leave ordered traffic's
/// decision accounting untouched: identical paths AND an identical
/// `decisions` counter with and without the cache, replay after replay.
#[test]
fn campaign_route_cache_keeps_ordered_decision_count() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut plain = Router::with_seed(&topo, 11);
    let mut cached = Router::with_seed(&topo, 11);
    cached.enable_route_cache();
    for round in 0..5 {
        for i in 0..8u32 {
            let f = Flow::new(i * 8, (i * 8 + 96) % 384, 1 << 16).ordered();
            assert_eq!(
                plain.route(&f),
                cached.route(&f),
                "round {round}: ordered paths must match"
            );
        }
    }
    assert_eq!(
        plain.decisions, cached.decisions,
        "the route cache must not change ordered decision counts"
    );
    assert_eq!(
        cached.route_cache_hits(),
        0,
        "ordered flows bypass the unordered memo entirely"
    );
    // and a closed-loop scenario's cached materialization stays
    // deterministic (covers the campaign golden/byte-diff contract)
    let s = Scenario::new(
        "rc",
        AuroraConfig::small(6, 4),
        DesOpts::default(),
        Workload::CollectiveIncast {
            ranks: 16,
            rounds: 6,
            bytes: 1 << 20,
            fanin: 6,
            congestor_bytes: 4 << 20,
        },
        3,
    );
    assert_eq!(s.run(), s.run(), "cached closed-loop scenario determinism");
}

// ----------------------------------------------------------- solver scratch

/// A reused [`DesScratch`] must be observationally identical to a fresh
/// one: interleave different workloads, DES options and executors
/// through ONE scratch and require bit-identical results — the property
/// the campaign workers and `World` supersteps rely on.
#[test]
fn scratch_reuse_is_history_independent() {
    use aurorasim::fabric::DesScratch;
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xE0A);
    let (timed_a, opts_a) = mixed_case(&topo, &mut rng, 20, 6, true, true);
    let (timed_b, opts_b) = mixed_case(&topo, &mut rng, 16, 0, false, false);
    let fresh_a = DesSim::new(&topo, opts_a.clone()).run(&timed_a);
    let fresh_b = DesSim::new(&topo, opts_b.clone()).run(&timed_b);
    let mut scratch = DesScratch::new();
    for pass in 0..3 {
        let ra = DesSim::new(&topo, opts_a.clone())
            .run_with(&timed_a, &mut scratch);
        assert_eq!(ra.finish, fresh_a.finish, "pass {pass}: open loop a");
        assert_eq!(ra.contributors, fresh_a.contributors);
        let rb = DesSim::new(&topo, opts_b.clone())
            .run_with(&timed_b, &mut scratch);
        assert_eq!(rb.finish, fresh_b.finish, "pass {pass}: open loop b");
    }
    // closed-loop and streaming through the same (now well-used) scratch
    let nics = workload::spread_nics(&topo, 10);
    let rr = workload::ring_rounds(&nics, 5, 1 << 20);
    let mut r1 = Router::with_seed(&topo, 13);
    let dag = workload::dag_from_rounds(&mut r1, &rr, 0.0);
    let sim = DesSim::new(&topo, DesOpts::default());
    let fresh_dag = sim.run_dag(&dag);
    let reused_dag = sim.run_dag_with(&dag, &mut scratch);
    assert_eq!(fresh_dag.node_finish, reused_dag.node_finish);
    let mut r2 = Router::with_seed(&topo, 13);
    let rv = rr.clone();
    let mut src =
        workload::routed_round_source(&mut r2, move |k| rv.get(k).cloned());
    let fresh_stream = sim.run_stream(&mut src);
    let mut r3 = Router::with_seed(&topo, 13);
    let rv2 = rr.clone();
    let mut src2 =
        workload::routed_round_source(&mut r3, move |k| rv2.get(k).cloned());
    let reused_stream = sim.run_stream_with(&mut src2, &mut scratch);
    assert_eq!(
        fresh_stream.makespan.to_bits(),
        reused_stream.makespan.to_bits(),
        "streamed: fresh vs reused scratch"
    );
    assert_eq!(fresh_stream.peak_live_nodes, reused_stream.peak_live_nodes);
    assert_eq!(fresh_stream.late_releases, reused_stream.late_releases);
}

// ------------------------------------------- component-parallel solve

/// A batch-parallel workload: 8 group-aligned halo blocks (link-disjoint
/// components) + a leader-ring allreduce fusing them + an incast clique
/// in a ninth group (contributor/victim classification under
/// partitioning). Halo batches carry ~384 flows over >= 8 components, so
/// the fan-out path engages past its work threshold.
fn multi_component_rounds(
    topo: &Topology,
    halo_rounds: usize,
) -> Vec<Vec<(u32, u32, u64)>> {
    let blocks = workload::group_blocks(topo, 8, 24);
    let mut rounds = workload::halo_allreduce_rounds(
        &blocks, halo_rounds, 1 << 20, 3, 2 << 20,
    );
    let epg = topo.cfg.endpoints_per_group() as u32;
    let root = 8 * epg + 33; // ninth group: disjoint from every block
    for i in 0..8u32 {
        rounds[0].push((8 * epg + i * 4, root, 4 << 20));
    }
    rounds
}

/// Tentpole acceptance: the component-parallel batch solve is
/// bit-identical to serial at every thread count — `DagResult` and
/// `StreamResult` compared at the `f64::to_bits` level (the campaign
/// byte-diff pattern applied to raw results) for threads in {1, 2, 8}.
#[test]
fn parallel_solve_bit_identical_across_thread_counts() {
    use aurorasim::fabric::DesScratch;
    let topo = Topology::new(&AuroraConfig::small(10, 4));
    let rounds = multi_component_rounds(&topo, 4);
    let mk_opts = |threads: usize| DesOpts {
        solver_threads: threads,
        ..DesOpts::default()
    };
    let mut dag_sig: Option<(Vec<u64>, usize, usize, u64)> = None;
    for &threads in &[1usize, 2, 8] {
        let mut router = Router::with_seed(&topo, 55);
        let dag = workload::dag_from_rounds(&mut router, &rounds, 0.0);
        let mut scratch = DesScratch::new();
        let sim = DesSim::new(&topo, mk_opts(threads));
        let res = sim.run_dag_with(&dag, &mut scratch);
        assert!(
            res.components_solved > res.solve_batches,
            "threads = {threads}: disjoint halo blocks must yield \
             multi-component batches ({} over {})",
            res.components_solved,
            res.solve_batches
        );
        if threads == 8 {
            assert!(
                scratch.fanned_batches() > 0,
                "8-thread run must exercise the fan-out path"
            );
        }
        let sig = (
            res.node_finish.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            res.contributors,
            res.victims,
            res.makespan.to_bits(),
        );
        match &dag_sig {
            None => dag_sig = Some(sig),
            Some(base) => assert_eq!(
                base, &sig,
                "threads = {threads}: DagResult must be bit-identical"
            ),
        }
    }
    // the streamed executor honours the same contract
    let mut stream_sig: Option<(u64, usize, usize, usize, usize)> = None;
    for &threads in &[1usize, 2, 8] {
        let mut router = Router::with_seed(&topo, 55);
        let rv = rounds.clone();
        let mut src = workload::routed_round_source(&mut router, move |k| {
            rv.get(k).cloned()
        });
        let res = DesSim::new(&topo, mk_opts(threads)).run_stream(&mut src);
        assert_eq!(res.late_releases, 0, "threads = {threads}");
        let sig = (
            res.makespan.to_bits(),
            res.contributors,
            res.victims,
            res.peak_live_nodes,
            res.total_nodes,
        );
        match &stream_sig {
            None => stream_sig = Some(sig),
            Some(base) => assert_eq!(
                base, &sig,
                "threads = {threads}: StreamResult must be bit-identical"
            ),
        }
    }
}

/// The partitioned walk + per-component solve must still reach the
/// oracle's fixpoint: sweep the multi-component incast+halo case against
/// the full-re-solve oracle with congestion management on and off.
#[test]
fn partitioned_solve_matches_oracle_on_multi_component_case() {
    let topo = Topology::new(&AuroraConfig::small(10, 4));
    let rounds = multi_component_rounds(&topo, 2);
    let mut r1 = Router::with_seed(&topo, 56);
    let dag = workload::dag_from_rounds(&mut r1, &rounds, 0.0);
    assert_dag_equivalent(
        &topo,
        &DesOpts::default(),
        &dag,
        "multi-component halo+allreduce+incast",
    );
    assert_dag_equivalent(
        &topo,
        &DesOpts { congestion_mgmt: false, ..DesOpts::default() },
        &dag,
        "multi-component halo+allreduce+incast nocm",
    );
}

// ---------------------------------------------------------- fault timeline

/// Fault-injection acceptance (EXPERIMENTS.md §Fault injection): a
/// fault timeline that degrades links at t=0 must price bit-identically
/// to the same degradation installed statically via `DesOpts::degraded`
/// — at every solver thread count. The t=0 fire path multiplies
/// pristine capacities exactly once, so `(bw * 1.0) * m == bw * m`
/// holds bitwise and the two runs share every intermediate.
#[test]
fn fault_t0_timeline_bit_identical_to_static_degraded_across_threads() {
    use aurorasim::fabric::faults::{FaultKind, FaultPolicy, FaultSchedule};
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xFA17);
    let (wl, opts) = closed_loop_case(&topo, &mut rng, 12, 4, 6, 5, true);
    assert!(!opts.degraded.is_empty(), "case must degrade some links");
    let mut fs = FaultSchedule::new(FaultPolicy::Reroute);
    for (l, m) in &opts.degraded {
        fs = fs
            .at(0.0, FaultKind::LinkDegrade { link: *l, multiplier: *m });
    }
    for &threads in &[1usize, 2, 8] {
        let mut static_opts = opts.clone();
        static_opts.solver_threads = threads;
        let mut fault_opts = opts.clone();
        fault_opts.degraded = BTreeMap::new();
        fault_opts.faults = Some(fs.clone());
        fault_opts.solver_threads = threads;
        let rs = DesSim::new(&topo, static_opts).run_dag(&wl);
        let rf = DesSim::new(&topo, fault_opts).run_dag(&wl);
        assert_eq!(rs.failed_flows, 0);
        assert_eq!(rf.failed_flows, 0);
        assert_eq!(
            rs.node_finish.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            rf.node_finish.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            "threads = {threads}: t=0 timeline vs static degraded"
        );
        assert_eq!(rs.makespan.to_bits(), rf.makespan.to_bits());
        assert_eq!(rs.contributors, rf.contributors);
        assert_eq!(rs.victims, rf.victims);
    }
}

/// A fault event and a flow completion sharing an exact timestamp: the
/// fault sweep runs first within the batch but must skip flows in the
/// batch's completion set — delivered bytes are never destroyed — so
/// the completing flow finishes at exactly its healthy time while a
/// still-in-flight flow crossing a downed link is failed by `Abort`.
#[test]
fn fault_and_completion_same_timestamp_tie_break() {
    use aurorasim::fabric::faults::{FaultKind, FaultPolicy, FaultSchedule};
    use aurorasim::topology::LinkId;
    let topo = Topology::new(&AuroraConfig::small(4, 4));
    let mut router = Router::with_seed(&topo, 61);
    // disjoint NIC-capped flows, B carrying twice A's bytes: B is
    // exactly half done when A completes
    let flows = [Flow::new(0, 200, 32 << 20), Flow::new(8, 208, 64 << 20)];
    let timed: Vec<TimedFlow> = flows
        .into_iter()
        .map(|f| TimedFlow {
            rf: RoutedFlow { path: router.route(&f), flow: f },
            start: 0.0,
        })
        .collect();
    let healthy = DesSim::new(&topo, DesOpts::default()).run(&timed);
    let t_c = healthy.finish[0];
    assert!(healthy.finish[1] > t_c, "B must still be in flight at t_c");
    // both uplinks go down at exactly A's completion time
    let fs = FaultSchedule::new(FaultPolicy::Abort)
        .at(t_c, FaultKind::LinkDown { link: LinkId::NicUp(0) })
        .at(t_c, FaultKind::LinkDown { link: LinkId::NicUp(8) });
    let res = DesSim::new(
        &topo,
        DesOpts { faults: Some(fs), ..DesOpts::default() },
    )
    .run(&timed);
    assert_eq!(res.failed_flows, 1, "only the in-flight flow fails");
    assert_eq!(
        res.finish[0].to_bits(),
        t_c.to_bits(),
        "a completion sharing the fault timestamp must still complete"
    );
    assert!(res.finish[1].is_nan(), "aborted flow reports NaN");
    assert_eq!(
        res.makespan.to_bits(),
        t_c.to_bits(),
        "failed flows are excluded from the makespan"
    );
}

// ------------------------------------------- single-bottleneck fast path

/// Raw-speed acceptance: the single-bottleneck fast path is bit-identical
/// to the general waterfill. Sweep fastpath {on, off} × threads {1, 2, 8}
/// on the mixed multi-component case — the shared-ejection incast and
/// lone-flow tail components qualify, while the 3-neighbour halo blocks
/// chain through per-NIC links with no single link carrying every flow
/// and must stay on the general path — and require identical `to_bits`
/// signatures throughout, then close the loop against the oracle.
#[test]
fn single_bottleneck_fastpath_bit_identical_across_thread_counts() {
    use aurorasim::fabric::DesScratch;
    let topo = Topology::new(&AuroraConfig::small(10, 4));
    let rounds = multi_component_rounds(&topo, 4);
    let mk = |threads: usize, fast: bool| DesOpts {
        solver_threads: threads,
        single_bottleneck_fastpath: fast,
        ..DesOpts::default()
    };
    let mut sig: Option<(Vec<u64>, usize, usize, u64)> = None;
    for &fast in &[true, false] {
        for &threads in &[1usize, 2, 8] {
            let mut router = Router::with_seed(&topo, 55);
            let dag = workload::dag_from_rounds(&mut router, &rounds, 0.0);
            let mut scratch = DesScratch::new();
            let res = DesSim::new(&topo, mk(threads, fast))
                .run_dag_with(&dag, &mut scratch);
            if fast {
                assert!(
                    res.fastpath_components > 0,
                    "threads = {threads}: the mixed case must contain \
                     qualifying components"
                );
                assert!(
                    res.fastpath_components < res.components_solved,
                    "threads = {threads}: chained halo components must \
                     stay on the general path ({} of {})",
                    res.fastpath_components,
                    res.components_solved
                );
            } else {
                assert_eq!(
                    res.fastpath_components, 0,
                    "threads = {threads}: fast path disabled"
                );
            }
            let s = (
                res.node_finish
                    .iter()
                    .map(|f| f.to_bits())
                    .collect::<Vec<_>>(),
                res.contributors,
                res.victims,
                res.makespan.to_bits(),
            );
            match &sig {
                None => sig = Some(s),
                Some(base) => assert_eq!(
                    base, &s,
                    "fastpath = {fast}, threads = {threads}: results \
                     must be bit-identical to the general path"
                ),
            }
        }
    }
    // the fast-pathed incremental solver still reaches the oracle
    // fixpoint, with the fast path on and off
    let mut r = Router::with_seed(&topo, 55);
    let dag = workload::dag_from_rounds(&mut r, &rounds, 0.0);
    assert_dag_equivalent(&topo, &mk(1, true), &dag, "fastpath vs oracle");
    assert_dag_equivalent(&topo, &mk(1, false), &dag, "general vs oracle");
}

/// Open-loop spot check of the same contract: `DesSim::run` with the
/// fast path on and off over seeded mixed workloads (incast cliques
/// qualify; degraded links and staggered arrivals exercise the guards),
/// bit-compared, plus the `fastpath_components` bookkeeping.
#[test]
fn single_bottleneck_fastpath_bit_identical_open_loop() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let mut rng = Pcg::new(0xFA57);
    let mut any_fast = 0usize;
    for case in 0..8usize {
        let (timed, opts) = mixed_case(
            &topo,
            &mut rng,
            12 + case,
            if case % 2 == 0 { 6 } else { 0 },
            case % 3 == 0,
            case % 2 == 1,
        );
        let on = DesSim::new(
            &topo,
            DesOpts { single_bottleneck_fastpath: true, ..opts.clone() },
        )
        .run(&timed);
        let off = DesSim::new(
            &topo,
            DesOpts { single_bottleneck_fastpath: false, ..opts.clone() },
        )
        .run(&timed);
        let bits = |f: &[f64]| {
            f.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(&on.finish), bits(&off.finish), "case {case}");
        assert_eq!(
            on.makespan.to_bits(),
            off.makespan.to_bits(),
            "case {case}"
        );
        assert_eq!(on.contributors, off.contributors, "case {case}");
        assert_eq!(on.victims, off.victims, "case {case}");
        assert_eq!(off.fastpath_components, 0, "case {case}: disabled");
        any_fast += on.fastpath_components;
    }
    assert!(any_fast > 0, "the sweep must exercise the fast path");
}

/// `World::set_degraded` installs §3.4 multipliers on BOTH pricing
/// layers at once: the DES prices degraded links at reduced capacity
/// (asserted here via NIC uplinks, which no adaptive decision can
/// route around) and the router's diversion/invalidation behaviour is
/// covered by the `fabric::routing` unit tests.
#[test]
fn world_set_degraded_reprices_both_layers() {
    use aurorasim::machine::Machine;
    use aurorasim::mpi::{coll, Comm, World};
    use aurorasim::topology::LinkId;
    let m = Machine::new(&AuroraConfig::small(6, 4));
    let comm = Comm::world(32);
    let mut clean =
        World::new(&m.topo, m.place_job(0, 32, 1)).des_fabric();
    let t_clean = coll::allreduce_ring_time(&mut clean, &comm, 8 << 20);
    let mut slow =
        World::new(&m.topo, m.place_job(0, 32, 1)).des_fabric();
    let degraded: BTreeMap<_, _> = slow
        .nics
        .iter()
        .map(|&n| (LinkId::NicUp(n), 0.1))
        .collect();
    slow.set_degraded(degraded);
    let t_slow = coll::allreduce_ring_time(&mut slow, &comm, 8 << 20);
    assert!(
        t_slow > t_clean * 2.0,
        "10%-bandwidth NIC uplinks must slow the ring allreduce: \
         {t_slow} vs {t_clean}"
    );
}

/// Campaign-wide zero-rebuild: a worker's [`DesScratch`] threaded
/// through every scenario of the standard sweep must be *reset*, never
/// *reallocated*, on the second pass — the capacity signature (sum of
/// every arena's heap capacity) is stable once the first sweep has
/// warmed it, and results stay equal to the first pass.
#[test]
fn campaign_worker_scratch_resets_without_reallocating() {
    use aurorasim::fabric::DesScratch;
    let cfg = AuroraConfig::small(4, 4);
    let scenarios = Campaign::standard(&cfg, 0xBEEF).scenarios;
    let mut scratch = DesScratch::new();
    let first: Vec<_> =
        scenarios.iter().map(|s| s.run_with(&mut scratch)).collect();
    let sig = scratch.capacity_signature();
    assert!(sig > 0, "warmed scratch must own allocations");
    let second: Vec<_> =
        scenarios.iter().map(|s| s.run_with(&mut scratch)).collect();
    assert_eq!(
        scratch.capacity_signature(),
        sig,
        "second sweep through a warmed worker scratch must not allocate"
    );
    assert_eq!(first, second, "reset scratch must not perturb results");
}

// ------------------------------------------------- streaming retirement

/// Per-node refcount retirement regression: a key touched once in round
/// 0 and never again must not pin round 0 — and with it every later
/// round — live for the whole run (the old prefix-round retirement kept
/// peak == total here).
#[test]
fn stream_retires_rounds_pinned_only_by_idle_keys() {
    let topo = Topology::new(&AuroraConfig::small(6, 4));
    let ring: Vec<u32> = (0..8u32).map(|i| i * 24).collect();
    let rounds_n = 40usize;
    let bytes = 1u64 << 20;
    let mut rounds: Vec<Vec<(u32, u32, u64)>> =
        workload::ring_rounds(&ring, rounds_n, bytes);
    rounds[0].push((300, 301, bytes)); // the once-touched pair
    let sim = DesSim::new(&topo, DesOpts::default());
    let mut r1 = Router::with_seed(&topo, 5);
    let dag = workload::dag_from_rounds(&mut r1, &rounds, 0.0);
    let full = sim.run_dag(&dag);
    let mut r2 = Router::with_seed(&topo, 5);
    let rv = rounds.clone();
    let mut src =
        workload::routed_round_source(&mut r2, move |k| rv.get(k).cloned());
    let res = sim.run_stream(&mut src);
    assert_eq!(res.late_releases, 0);
    assert_eq!(res.total_nodes, dag.len());
    let rel = (res.makespan - full.makespan).abs() / full.makespan.max(1e-30);
    assert!(rel < REL_TOL, "sparse-key stream vs materialized");
    assert!(
        res.peak_live_nodes * 2 < res.total_nodes,
        "peak {} of {} — an idle key must not pin the window",
        res.peak_live_nodes,
        res.total_nodes
    );
}

// ------------------------------------------------- streamed superstep flush

/// The streamed superstep flush must price identically (1e-9) to the
/// fully materialized flush on every app step driver — and take the
/// streamed path exactly when the staged structure is provably exact
/// (hacc / lammps exchange loops re-touch every rank each round; the
/// amr tree-allreduce flush at 12 ranks leaves remainder-rank gaps and
/// falls back).
#[test]
fn superstep_streamed_flush_matches_materialized() {
    use aurorasim::apps;
    use aurorasim::machine::Machine;
    use aurorasim::mpi::World;
    let m = Machine::new(&AuroraConfig::small(6, 4));
    for (what, expect_streamed) in
        [("hacc", true), ("lammps", true), ("amr_wind", false)]
    {
        let drive = |w: &mut World| match what {
            "hacc" => apps::hacc::step_world(w, 12, 8 << 20),
            "lammps" => apps::lammps::step_world(w, 12, 8 << 20),
            _ => apps::amr_wind::step_world(w, 12, 1 << 20),
        };
        let mut ws = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let ts = drive(&mut ws);
        let fs = ws.last_flush.expect("superstep flushed");
        assert_eq!(fs.streamed, expect_streamed, "{what}: flush path");
        assert_eq!(fs.late_releases, 0, "{what}: exactness");
        let mut wm = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        wm.superstep_streaming(false);
        let tm = drive(&mut wm);
        let rel = (ts - tm).abs() / tm.abs().max(1e-30);
        assert!(rel < REL_TOL, "{what}: streamed {ts} vs materialized {tm}");
        for (r, (a, b)) in ws.clock.iter().zip(&wm.clock).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1e-30);
            assert!(rel < REL_TOL, "{what} rank {r}: {a} vs {b}");
        }
        if expect_streamed {
            assert!(
                fs.peak_live_nodes < fs.total_nodes,
                "{what}: windowed flush must retire rounds \
                 (peak {} of {})",
                fs.peak_live_nodes,
                fs.total_nodes
            );
        }
    }
}

// ---------------------------------------------------------------- campaign

#[test]
fn campaign_parallel_matches_serial_byte_for_byte() {
    let cfg = AuroraConfig::small(6, 4);
    let campaign = Campaign::standard(&cfg, 0xC0FFEE);
    let serial = campaign.run_serial().to_json().dump_pretty();
    for threads in [2usize, 4, 8] {
        let parallel = campaign.run(threads).to_json().dump_pretty();
        assert_eq!(serial, parallel, "threads = {threads}");
    }
}

#[test]
fn campaign_is_seed_stable_across_scenario_order() {
    // seeds derive from names, so reordering scenarios must not change
    // any individual result
    let cfg = AuroraConfig::small(4, 4);
    let fwd = Campaign::standard(&cfg, 7).run_serial();
    let mut rev = Campaign::standard(&cfg, 7);
    rev.scenarios.reverse();
    let bwd = rev.run_serial();
    for r in &fwd.results {
        let other = bwd
            .results
            .iter()
            .find(|o| o.name == r.name)
            .expect("scenario present in both orders");
        assert_eq!(r, other, "{}", r.name);
    }
}

#[test]
fn campaign_scenarios_run_under_both_solvers() {
    // every standard workload, replayed through the oracle: the campaign
    // engine's results must not depend on which solver is used.
    // Closed-loop scenarios go through the DAG solver pair.
    let cfg = AuroraConfig::small(4, 4);
    for s in &Campaign::standard(&cfg, 3).scenarios {
        let topo = Topology::new(&s.cfg);
        if let Some((wl, opts)) = s.materialize_dag(&topo) {
            assert_dag_equivalent(&topo, &opts, &wl, &s.name);
            continue;
        }
        let (timed, opts) = s.materialize(&topo);
        if timed.is_empty() {
            continue;
        }
        assert_equivalent(&topo, &opts, &timed, &s.name);
    }
}

#[test]
fn custom_scenario_roundtrip() {
    let cfg = AuroraConfig::small(4, 4);
    let s = Scenario::new(
        "custom",
        cfg,
        DesOpts::default(),
        Workload::Staggered { flows: 40, bytes: 2 << 20, window_s: 0.01 },
        99,
    );
    let a = s.run();
    let b = s.run();
    assert_eq!(a, b, "scenario execution must be deterministic");
    assert!(a.makespan > 0.0);
}
