//! PJRT runtime benchmarks: artifact execution throughput — the L1/L2
//! compute path as seen from the Rust hot loop. Skips (with a notice) if
//! `make artifacts` has not run.

use aurorasim::runtime::Runtime;
use std::time::Instant;

fn bench_artifact(rt: &mut Runtime, name: &str, iters: usize) {
    let spec = match rt.manifest.get(name) {
        Some(s) => s.clone(),
        None => {
            println!("{name:<28} MISSING");
            return;
        }
    };
    let args: Vec<Vec<f64>> =
        spec.args.iter().map(|a| vec![0.5; a.elems()]).collect();
    let refs: Vec<&[f64]> = args.iter().map(|v| v.as_slice()).collect();
    // first call compiles
    let t0 = Instant::now();
    rt.call_f64(name, &refs).expect(name);
    let compile_and_first = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(rt.call_f64(name, &refs).expect(name));
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let gflops = rt.flops(name) / per / 1e9;
    println!(
        "{name:<28} {:>10.3} ms/call  {gflops:>8.2} GF/s  (compile+1st \
         {:.0} ms)",
        per * 1e3,
        compile_and_first * 1e3
    );
}

fn main() {
    println!("== PJRT runtime benches ==");
    let mut rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP: {e}");
            return;
        }
    };
    println!("platform: {}", rt.platform());
    for (name, iters) in [
        ("hpl_update", 50),
        ("hpl_panel_factor", 20),
        ("hpl_trsm_row", 20),
        ("mxp_update", 50),
        ("mxp_gemm", 20),
        ("hpcg_spmv", 30),
        ("hpcg_symgs", 20),
        ("hpcg_dot", 100),
        ("hacc_fft_poisson", 20),
        ("hacc_short_range", 30),
        ("nekbone_ax", 50),
        ("lammps_pair_tile", 50),
    ] {
        bench_artifact(&mut rt, name, iters);
    }
}
