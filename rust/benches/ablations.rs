//! Ablation studies for the design choices DESIGN.md calls out: what the
//! simulated Aurora loses when each Slingshot/config feature is turned
//! off. Each section prints feature-on vs feature-off for the metric the
//! paper motivates the feature with.

use aurorasim::config::AuroraConfig;
use aurorasim::fabric::analytic;
use aurorasim::fabric::des::{DesOpts, DesSim};
use aurorasim::fabric::{Flow, RoutedFlow, Router};
use aurorasim::machine::Machine;
use aurorasim::mpi::{coll, Comm, World};
use aurorasim::util::Pcg;

fn main() {
    println!("== ablation: adaptive routing / group-load setting (§4.2.1) ==");
    // hot group pair + load-aware vs probabilistic Valiant choice
    for group_load in [true, false] {
        let mut cfg = AuroraConfig::small(8, 4);
        cfg.group_load_setting = group_load;
        let m = Machine::new(&cfg);
        let mut router = Router::new(&m.topo);
        let mut flows = Vec::new();
        for i in 0..400 {
            let f = Flow::new((i % 16) as u32, 300 + (i % 16) as u32, 1 << 20);
            flows.push(RoutedFlow { path: router.route(&f), flow: f });
        }
        let res = DesSim::new(&m.topo, DesOpts::default())
            .run_simultaneous(&flows);
        println!(
            "  group_load={group_load:<5}  nonminimal {}  makespan {:.2} ms",
            router.nonminimal_count,
            res.makespan * 1e3
        );
    }

    println!("\n== ablation: congestion management (§3.1, Fig 5) ==");
    let m = Machine::new(&AuroraConfig::small(8, 4));
    let mut rng = Pcg::new(5);
    let mut router = Router::new(&m.topo);
    let mut flows = Vec::new();
    for i in 0..12 {
        let f = Flow::new((i * 8) as u32, 200, 8 << 20); // incast
        flows.push(RoutedFlow { path: router.route(&f), flow: f });
    }
    for _ in 0..24 {
        // background victims
        let s = rng.gen_usize(64) as u32 * 8;
        let d = 256 + rng.gen_usize(200) as u32;
        if s != d {
            let f = Flow::new(s, d, 1 << 20);
            flows.push(RoutedFlow { path: router.route(&f), flow: f });
        }
    }
    for mgmt in [true, false] {
        let res = DesSim::new(
            &m.topo,
            DesOpts { congestion_mgmt: mgmt, ..DesOpts::default() },
        )
        .run_simultaneous(&flows);
        let victims: Vec<f64> = res.per_flow[12..].to_vec();
        let avg = victims.iter().sum::<f64>() / victims.len() as f64;
        println!(
            "  congestion_mgmt={mgmt:<5}  victim avg completion {:.2} ms",
            avg * 1e3
        );
    }

    println!("\n== ablation: allreduce algorithm switch (Fig 14) ==");
    let m = Machine::new(&AuroraConfig::small(16, 8));
    for bytes in [8u64, 64 << 10, 16 << 20] {
        let mut w1 = World::new(&m.topo, m.place_job(0, 128, 1));
        let tree =
            coll::allreduce_tree_time(&mut w1, &Comm::world(128), bytes);
        let mut w2 = World::new(&m.topo, m.place_job(0, 128, 1));
        let ring =
            coll::allreduce_ring_time(&mut w2, &Comm::world(128), bytes);
        println!(
            "  {bytes:>9} B: tree {:>10.1} us   ring {:>10.1} us   winner: {}",
            tree * 1e6,
            ring * 1e6,
            if tree < ring { "tree" } else { "ring" }
        );
    }

    println!("\n== ablation: adaptive-routing tax on all2all (Fig 4) ==");
    let cfg = AuroraConfig::aurora();
    let real = analytic::alltoall_aggregate_bw(&cfg, 9658, 16, 1 << 20);
    let theory = analytic::alltoall_theoretical_bw(&cfg, 9658);
    println!(
        "  achieved {:.2} TB/s vs wire-limit {:.2} TB/s  ({:.0}% tax)",
        real / 1e12,
        theory / 1e12,
        (1.0 - real / theory) * 100.0
    );

    println!("\n== ablation: NIC balancing across sockets (§5.1/Fig 13) ==");
    // balanced (paper) vs all-ranks-on-one-NIC binding
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let balanced = aurorasim::apps::osu::socket_bandwidth(&m, 4, false);
    let one_nic = aurorasim::apps::osu::single_nic_gpu_bw(&m, 4, 64 << 20);
    println!(
        "  balanced 4 ranks: {:.1} GB/s   all on one NIC: {:.1} GB/s",
        balanced / 1e9,
        one_nic / 1e9
    );

    println!("\n== ablation: DES solver — incremental vs dense oracle ==");
    // what the incremental component re-solve buys on the mixed pattern
    // the campaign engine leans on (EXPERIMENTS.md §Perf)
    let topo = Machine::new(&AuroraConfig::small(16, 16)).topo.clone();
    let mut router = Router::with_seed(&topo, 17);
    let mut rng2 = Pcg::new(23);
    let nics = topo.cfg.compute_endpoints() as u64;
    for n in [512usize, 2048] {
        let mut flows = Vec::with_capacity(n);
        // 1/4 incast traffic onto 8 roots, 3/4 uniform background
        for i in 0..n {
            let f = if i % 4 == 0 {
                let root = ((i / 4) % 8) as u32 * 64 + 5;
                Flow::new(rng2.gen_range(nics) as u32, root, 2 << 20)
            } else {
                let s = rng2.gen_range(nics) as u32;
                let d = ((s as u64 + 1 + rng2.gen_range(nics - 1)) % nics)
                    as u32;
                Flow::new(s, d, 1 << 20)
            };
            flows.push(RoutedFlow { path: router.route(&f), flow: f });
        }
        let sim = DesSim::new(&topo, DesOpts::default());
        let t0 = std::time::Instant::now();
        let inc = sim.run_simultaneous(&flows);
        let t_inc = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let ora = sim.run_simultaneous_oracle(&flows);
        let t_ora = t0.elapsed().as_secs_f64();
        println!(
            "  {n:>5} flows: incremental {:>9.2} ms  oracle {:>9.2} ms  \
             ({:.1}x)  makespan delta {:+.2e}",
            t_inc * 1e3,
            t_ora * 1e3,
            t_ora / t_inc.max(1e-12),
            inc.makespan - ora.makespan
        );
    }

    println!("\n== ablation: campaign engine — serial vs parallel ==");
    let cfg = AuroraConfig::small(8, 4);
    let campaign = aurorasim::campaign::Campaign::standard(
        &cfg,
        aurorasim::reproduce::CAMPAIGN_SEED,
    );
    let t0 = std::time::Instant::now();
    let serial = campaign.run_serial();
    let t_ser = t0.elapsed().as_secs_f64();
    let threads = aurorasim::campaign::pool::default_threads();
    let t0 = std::time::Instant::now();
    let parallel = campaign.run(threads);
    let t_par = t0.elapsed().as_secs_f64();
    println!(
        "  {} scenarios: serial {:.2} ms   {} threads {:.2} ms ({:.1}x)   \
         byte-identical: {}",
        serial.results.len(),
        t_ser * 1e3,
        threads,
        t_par * 1e3,
        t_ser / t_par.max(1e-12),
        serial.to_json().dump() == parallel.to_json().dump()
    );
}
