//! Collective-algorithm benchmarks: cost of evaluating the Fig 14 sweep
//! points (tree vs ring allreduce, all2all rounds, bcast) — both the
//! simulated latencies and the simulator's own evaluation cost.

use aurorasim::config::AuroraConfig;
use aurorasim::machine::Machine;
use aurorasim::mpi::{coll, Comm, World};
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    for _ in 0..iters.div_ceil(10).min(3) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<48} {:>12.3} us/iter  ({iters} iters)", per * 1e6);
}

fn main() {
    println!("== collective benches ==");
    let m2048 = Machine::new(&AuroraConfig::small(32, 32)); // 2,048 nodes
    let m256 = Machine::new(&AuroraConfig::small(16, 8));   // 256 nodes

    for nodes in [64usize, 512, 2048] {
        bench(&format!("allreduce/tree 8B ({nodes} nodes)"),
              if nodes > 512 { 5 } else { 20 }, || {
            let mut w =
                World::new(&m2048.topo, m2048.place_job(0, nodes, 1));
            let comm = Comm::world(nodes);
            std::hint::black_box(
                coll::allreduce_tree_time(&mut w, &comm, 8));
        });
    }

    for nodes in [64usize, 512, 2048] {
        bench(&format!("allreduce/ring 16MiB ({nodes} nodes)"),
              if nodes > 512 { 5 } else { 20 }, || {
            let mut w =
                World::new(&m2048.topo, m2048.place_job(0, nodes, 1));
            let comm = Comm::world(nodes);
            std::hint::black_box(
                coll::allreduce_ring_time(&mut w, &comm, 16 << 20));
        });
    }

    bench("alltoall/64KiB (128 ranks, sampled rounds)", 10, || {
        let mut w = World::new(&m256.topo, m256.place_job(0, 64, 2));
        let comm = Comm::world(128);
        std::hint::black_box(coll::alltoall(&mut w, &comm, 64 << 10));
    });

    bench("bcast/1MiB (256 nodes, binomial)", 10, || {
        let mut w = World::new(&m256.topo, m256.place_job(0, 256, 1));
        let comm = Comm::world(256);
        std::hint::black_box(coll::bcast(&mut w, &comm, 0, 1 << 20));
    });

    bench("barrier (256 nodes)", 10, || {
        let mut w = World::new(&m256.topo, m256.place_job(0, 256, 1));
        let comm = Comm::world(256);
        std::hint::black_box(coll::barrier(&mut w, &comm));
    });

    // the full Fig 14 sweep — the figure-regeneration cost target
    bench("fig14/full sweep (6 node counts x 5 sizes)", 3, || {
        let nodes = aurorasim::apps::allreduce::fig14_nodes(&m2048);
        let sizes = aurorasim::apps::allreduce::fig14_sizes();
        std::hint::black_box(
            aurorasim::apps::allreduce::sweep(&m2048, &nodes, &sizes));
    });
}
