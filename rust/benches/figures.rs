//! One bench per paper table/figure: times the regeneration of each
//! experiment through the reproduce harness. This is the "regenerate the
//! evaluation section" cost — the practical inner loop of the repo.

use aurorasim::reproduce;
use std::time::Instant;

fn main() {
    println!("== figure-regeneration benches ==");
    let mut total = 0.0;
    for id in reproduce::all_ids() {
        let t0 = Instant::now();
        let out = reproduce::run(id).expect(id);
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("{id:<10} {:>10.1} ms  ({} bytes of report)",
                 dt * 1e3, out.len());
    }
    println!("total: {total:.2} s for {} experiments",
             reproduce::all_ids().len());
}
