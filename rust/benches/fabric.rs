//! Fabric-simulator hot-path benchmarks: adaptive routing throughput, the
//! max-min DES solver (open- and closed-loop), round evaluation at scale.
//! These are the L3 paths the §Perf pass optimizes (see EXPERIMENTS.md
//! §Perf).
//!
//! Hand-rolled harness (offline build — no criterion): prints
//! `name: time/iter` rows; `cargo bench --bench fabric` runs it. With
//! `BENCH_JSON=<path>` set, a machine-readable report is also written —
//! `{schema, bench, metrics: {key: {us_per_iter}}, ratios: {...}}` — and
//! compared against `ci/bench_baseline.json` by `ci/check_bench.py` (the
//! CI bench-regression gate; EXPERIMENTS.md §Bench gate).

use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{DesOpts, DesSim};
use aurorasim::fabric::rounds::CostModel;
use aurorasim::fabric::{workload, Flow, RoutedFlow, Router};
use aurorasim::topology::Topology;
use aurorasim::util::{Json, Pcg};
use std::collections::BTreeMap;
use std::time::Instant;

/// Collected results: metric key -> seconds/iter, plus derived ratios.
#[derive(Default)]
struct Report {
    metrics: Vec<(String, f64)>,
    ratios: Vec<(String, f64)>,
}

impl Report {
    /// Time `f` and record it under `key` (also printed human-readably).
    fn timed<F: FnMut()>(
        &mut self,
        key: &str,
        name: &str,
        iters: usize,
        mut f: F,
    ) -> f64 {
        for _ in 0..iters.div_ceil(10).min(3) {
            f(); // warmup
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!("{name:<48} {:>12.3} us/iter  ({iters} iters)", per * 1e6);
        self.metrics.push((key.to_string(), per));
        per
    }

    fn ratio(&mut self, key: &str, value: f64) {
        self.ratios.push((key.to_string(), value));
    }

    /// Record an externally timed metric (one-shot benches whose run
    /// also produces data for a ratio, e.g. the streaming executor's
    /// live-node headroom).
    fn record(&mut self, key: &str, name: &str, seconds: f64) {
        println!("{name:<48} {:>12.3} us/iter  (1 iter)", seconds * 1e6);
        self.metrics.push((key.to_string(), seconds));
    }

    /// Deterministic JSON (BTreeMap key order) for the CI gate.
    fn to_json(&self) -> Json {
        let metrics: BTreeMap<String, Json> = self
            .metrics
            .iter()
            .map(|(k, v)| {
                (
                    k.clone(),
                    Json::obj(vec![("us_per_iter", Json::num(v * 1e6))]),
                )
            })
            .collect();
        let ratios: BTreeMap<String, Json> = self
            .ratios
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v)))
            .collect();
        Json::obj(vec![
            ("schema", Json::str("aurorasim.bench/v1")),
            ("bench", Json::str("fabric")),
            ("metrics", Json::Obj(metrics)),
            ("ratios", Json::Obj(ratios)),
        ])
    }
}

fn random_flows(topo: &Topology, n: usize, seed: u64) -> Vec<RoutedFlow> {
    let mut rng = Pcg::new(seed);
    let mut router = Router::with_seed(topo, seed);
    let nics = topo.cfg.compute_endpoints() as u64;
    (0..n)
        .map(|_| {
            let src = rng.gen_range(nics) as u32;
            let dst = (src + 1 + rng.gen_range(nics - 1) as u32) % nics as u32;
            let f = Flow::new(src, dst, 1 << 20);
            RoutedFlow { path: router.route(&f), flow: f }
        })
        .collect()
}

fn main() {
    println!("== fabric benches ==");
    let mut rep = Report::default();
    let aurora = Topology::new(&AuroraConfig::aurora());
    let small = Topology::new(&AuroraConfig::small(16, 16));

    // routing on the full 84,992-NIC machine
    rep.timed("route_aurora_1k", "route/aurora (1k flows, adaptive)", 20,
        || {
            let mut router = Router::with_seed(&aurora, 7);
            let mut rng = Pcg::new(9);
            for _ in 0..1000 {
                let src = rng.gen_range(84_992) as u32;
                let dst = (src + 4096) % 84_992;
                std::hint::black_box(
                    router.route(&Flow::new(src, dst, 65536)));
            }
        });

    // round evaluation at three sizes
    for n in [100usize, 1_000, 10_000] {
        let flows = random_flows(&aurora, n, 11);
        let cm = CostModel::new(&aurora);
        rep.timed(
            &format!("eval_round_aurora_{n}"),
            &format!("eval_round/aurora ({n} flows)"),
            if n >= 10_000 { 5 } else { 30 },
            || {
                std::hint::black_box(cm.eval_round(&flows));
            },
        );
    }

    // DES: incremental component solver vs the dense full-recompute
    // oracle (EXPERIMENTS.md §Perf; acceptance: >= 5x at 2048 flows).
    // The oracle is skipped at 8192 unless BENCH_ORACLE_8192=1 — it is
    // O(events x flows x links) and takes minutes there.
    for n in [32usize, 128, 512, 2048, 8192] {
        let flows = random_flows(&small, n, 13);
        let iters = match n {
            0..=128 => 10,
            129..=512 => 3,
            _ => 1,
        };
        let inc = rep.timed(
            &format!("des_incremental_{n}"),
            &format!("des/incremental ({n} flows)"),
            iters,
            || {
                let sim = DesSim::new(&small, DesOpts::default());
                std::hint::black_box(sim.run_simultaneous(&flows));
            },
        );
        let run_oracle =
            n < 8192 || std::env::var_os("BENCH_ORACLE_8192").is_some();
        if run_oracle {
            let ora = rep.timed(
                &format!("des_oracle_{n}"),
                &format!("des/oracle      ({n} flows)"),
                iters,
                || {
                    let sim = DesSim::new(&small, DesOpts::default());
                    std::hint::black_box(
                        sim.run_simultaneous_oracle(&flows));
                },
            );
            println!(
                "des/speedup     ({n} flows)                      {:>10.1}x",
                ora / inc
            );
            rep.ratio(&format!("des_speedup_{n}"), ora / inc);
        }
    }

    // closed-loop DES: dependency-released ring rounds (the PR-2
    // injection layer), incremental vs full-re-solve oracle
    {
        let nics = workload::spread_nics(&small, 32);
        let mut router = Router::with_seed(&small, 17);
        let rr = workload::ring_rounds(&nics, 16, 1 << 20);
        let dag = workload::dag_from_rounds(&mut router, &rr, 0.0);
        let inc = rep.timed(
            "des_dag_ring_32x16",
            "des/dag ring 32 ranks x 16 rounds",
            5,
            || {
                let sim = DesSim::new(&small, DesOpts::default());
                std::hint::black_box(sim.run_dag(&dag));
            },
        );
        let ora = rep.timed(
            "des_dag_oracle_ring_32x16",
            "des/dag-oracle ring 32 ranks x 16 rounds",
            5,
            || {
                let sim = DesSim::new(&small, DesOpts::default());
                std::hint::black_box(sim.run_dag_oracle(&dag));
            },
        );
        rep.ratio("des_dag_speedup_ring_32x16", ora / inc);
    }

    // fault-injection hook overhead: the same closed-loop ring priced
    // healthy vs with a fault timeline armed whose only event sits far
    // beyond the makespan. Arming a non-empty schedule turns on the
    // whole fault path — the EV_FAULT heap entry and the per-batch
    // fault sweep — but no capacity ever changes, so the two runs must
    // agree bit-for-bit and the gated ratio (healthy time / armed
    // time, floor 0.95) bounds the bookkeeping cost of carrying a
    // timeline at ~5%.
    {
        use aurorasim::fabric::faults::{
            FaultKind, FaultPolicy, FaultSchedule,
        };
        use aurorasim::topology::LinkId;
        let nics = workload::spread_nics(&small, 32);
        let mut router = Router::with_seed(&small, 59);
        let rr = workload::ring_rounds(&nics, 16, 1 << 20);
        let dag = workload::dag_from_rounds(&mut router, &rr, 0.0);
        let fs = FaultSchedule::new(FaultPolicy::Reroute).at(
            1e6, // far beyond any makespan here: the hook stays idle
            FaultKind::LinkDegrade {
                link: LinkId::NicUp(0),
                multiplier: 0.5,
            },
        );
        let armed_opts = DesOpts { faults: Some(fs), ..DesOpts::default() };
        let rh = DesSim::new(&small, DesOpts::default()).run_dag(&dag);
        let ra = DesSim::new(&small, armed_opts.clone()).run_dag(&dag);
        assert_eq!(
            rh.node_finish.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            ra.node_finish.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "an armed-but-idle fault timeline must not perturb results"
        );
        assert_eq!(ra.failed_flows, 0);
        let healthy = rep.timed(
            "des_dag_ring_32x16_no_faults",
            "des/dag ring 32x16, no fault timeline",
            5,
            || {
                let sim = DesSim::new(&small, DesOpts::default());
                std::hint::black_box(sim.run_dag(&dag));
            },
        );
        let armed = rep.timed(
            "des_dag_ring_32x16_faults_armed",
            "des/dag ring 32x16, fault timeline armed",
            5,
            || {
                let sim = DesSim::new(&small, armed_opts.clone());
                std::hint::black_box(sim.run_dag(&dag));
            },
        );
        let overhead = healthy / armed;
        println!(
            "des/fault hook overhead (armed/healthy)          {:>10.2}x",
            armed / healthy
        );
        rep.ratio("fault_overhead", overhead);
    }

    // streaming closed-loop executor at Fig 14 scale: 2,048 endpoints of
    // dependency-released ring-allreduce rounds. The scale win is gated
    // machine-independently through the live-node headroom ratio
    // (total materialized nodes / peak live nodes): the windowed
    // executor must keep only a dependency-skew window of rounds in
    // memory, where full materialization would hold every routed flow.
    {
        let p = 2048usize;
        let rounds = 24usize;
        let nics = workload::spread_nics(&small, p);
        // equal 1 MiB chunks: per-endpoint round times are near-identical
        // (NIC-cap-limited), so the dependency skew — the live window —
        // stays at a few rounds of the 24
        let rr = workload::ring_rounds(&nics, rounds, 1 << 20);
        let sim = DesSim::new(&small, DesOpts::default());
        let run = || {
            let mut router = Router::with_seed(&small, 29);
            let rv = rr.clone();
            let mut src =
                workload::routed_round_source(&mut router, move |k| {
                    rv.get(k).cloned()
                });
            sim.run_stream(&mut src)
        };
        // warmup run (cold allocator/page-cache), then the timed run —
        // matching the warmup discipline of every other gated metric
        std::hint::black_box(run());
        let t0 = Instant::now();
        let res = run();
        let dt = t0.elapsed().as_secs_f64();
        rep.record(
            "des_stream_ring_2048",
            "des/stream ring 2048 ranks x 24 rounds",
            dt,
        );
        assert_eq!(res.late_releases, 0, "streamed ring must stay exact");
        let headroom = res.total_nodes as f64 / res.peak_live_nodes as f64;
        println!(
            "des/stream live-node headroom (2048)             {headroom:>10.1}x \
             (peak {} of {})",
            res.peak_live_nodes, res.total_nodes
        );
        rep.ratio("stream_live_headroom_ring_2048", headroom);
    }

    // route cache: 2,048-endpoint ring rounds re-route the same (src,
    // dst) pair once per round without the cache, once per PAIR with
    // it. The gated ratio is machine-independent: adaptive decisions
    // made uncached vs cached (= the round count, 24x here; floor 2).
    {
        let p = 2048usize;
        let rounds_n = 24usize;
        let nics = workload::spread_nics(&small, p);
        let rr = workload::ring_rounds(&nics, rounds_n, 1 << 20);
        let route_all = |r: &mut Router| {
            for round in &rr {
                for &(s, d, b) in round {
                    std::hint::black_box(r.route(&Flow::new(s, d, b)));
                }
            }
        };
        let mut decisions_uncached = 0usize;
        rep.timed(
            "des_route_cache_ring_2048_uncached",
            "route/ring 2048 x 24 rounds uncached",
            3,
            || {
                let mut r = Router::with_seed(&small, 31);
                route_all(&mut r);
                decisions_uncached = r.decisions;
            },
        );
        let mut decisions_cached = 0usize;
        rep.timed(
            "des_route_cache_ring_2048",
            "route/ring 2048 x 24 rounds cached",
            3,
            || {
                let mut r = Router::with_seed(&small, 31);
                r.enable_route_cache();
                route_all(&mut r);
                decisions_cached = r.decisions;
            },
        );
        let ratio =
            decisions_uncached as f64 / decisions_cached.max(1) as f64;
        println!(
            "route/cache decision ratio (2048-ring)           {ratio:>10.1}x \
             ({decisions_uncached} vs {decisions_cached} decisions)"
        );
        rep.ratio("route_cache_decision_ratio_ring_2048", ratio);
    }

    // streamed superstep flush at app-loop scale: 2,048 ranks x 16
    // exchange rounds staged into ONE dependency-released superstep and
    // priced on the windowed executor — the staged triples are
    // lightweight, and only a dependency-skew window of routed nodes is
    // ever live (the headroom ratio below is the machine-independent
    // gate; full materialization would hold all rounds x ranks nodes).
    {
        use aurorasim::machine::Machine;
        use aurorasim::mpi::World;
        let mch = Machine::new(&AuroraConfig::small(16, 16)); // 512 nodes
        let p = 2048usize;
        let rounds_n = 16usize;
        let run = || {
            let mut w =
                World::new(&mch.topo, mch.place_job(0, 512, 4)).des_fabric();
            w.begin_superstep();
            for _ in 0..rounds_n {
                let msgs: Vec<(usize, usize, u64)> =
                    (0..p).map(|i| (i, (i + 4) % p, 1 << 20)).collect();
                w.exchange(&msgs);
            }
            let span = w.end_superstep();
            let fs = w.last_flush.expect("superstep flushed");
            (span, fs)
        };
        std::hint::black_box(run()); // warmup (cold allocator/page cache)
        let t0 = Instant::now();
        let (span, fs) = run();
        let dt = t0.elapsed().as_secs_f64();
        rep.record(
            "des_superstep_stream_flush",
            "des/superstep streamed flush 2048 ranks x 16",
            dt,
        );
        assert!(fs.streamed, "app-loop superstep must stream its flush");
        assert_eq!(fs.late_releases, 0, "streamed flush must stay exact");
        assert!(span > 0.0);
        let headroom = fs.total_nodes as f64 / fs.peak_live_nodes as f64;
        println!(
            "des/superstep flush live-node headroom (2048)    {headroom:>10.1}x \
             (peak {} of {})",
            fs.peak_live_nodes, fs.total_nodes
        );
        rep.ratio("superstep_flush_headroom_2048", headroom);
    }

    // component-parallel DES at full-Aurora scale: 128 group-aligned
    // halo blocks of 128 endpoints (16,384 simulated endpoints on the
    // 84,992-NIC machine) plus a chunked leader-ring allreduce, streamed
    // with the per-batch component solves fanned over all cores. The
    // gated ratio is machine-independent: link-disjoint components
    // solved per event batch (halo batches carry up to 128; the fused
    // allreduce batches carry 1; floor 2 guards that the partitioned
    // walk keeps exposing parallel components at all).
    {
        use aurorasim::campaign::pool;
        use aurorasim::fabric::DesScratch;
        let full = Topology::new(&AuroraConfig::full_aurora());
        let groups = 128usize;
        let per_group = 128usize; // 16,384 endpoints
        let blocks = workload::group_blocks(&full, groups, per_group);
        let rounds =
            workload::halo_allreduce_rounds(&blocks, 2, 1 << 20, 8, 4 << 20);
        let opts = DesOpts {
            solver_threads: pool::default_threads(),
            ..DesOpts::default()
        };
        let sim = DesSim::new(&full, opts);
        let mut scratch = DesScratch::new();
        let run = |scratch: &mut DesScratch| {
            let mut router = Router::with_seed(&full, 37);
            let rv = rounds.clone();
            let mut src = workload::routed_round_source(&mut router, move |k| {
                rv.get(k).cloned()
            });
            sim.run_stream_with(&mut src, scratch)
        };
        std::hint::black_box(run(&mut scratch)); // warmup
        let t0 = Instant::now();
        let res = run(&mut scratch);
        let dt = t0.elapsed().as_secs_f64();
        rep.record(
            "des_component_parallel_full_aurora",
            "des/component-parallel full-aurora 16384 ep",
            dt,
        );
        assert_eq!(res.total_nodes, 2 * groups * per_group * 2 + 8 * groups);
        assert_eq!(res.late_releases, 0, "full-aurora stream must stay exact");
        let per_batch =
            res.components_solved as f64 / res.solve_batches.max(1) as f64;
        println!(
            "des/full-aurora components per batch              {per_batch:>10.1} \
             ({} components over {} batches, {} fanned)",
            res.components_solved,
            res.solve_batches,
            scratch.fanned_batches()
        );
        assert!(
            per_batch >= 2.0,
            "multi-group halos must expose >= 2 components per batch"
        );
        rep.ratio("parallel_components_per_batch", per_batch);
        // the NIC-bound equal-share components of the same run — lone
        // ring-chunk hops and shared-ejection tails — must keep hitting
        // the single-bottleneck fast path (floor 1 per batch)
        let fast_per_batch = res.fastpath_components as f64
            / res.solve_batches.max(1) as f64;
        println!(
            "des/full-aurora fast-path components per batch    {fast_per_batch:>10.1} \
             ({} of {} components)",
            res.fastpath_components, res.components_solved
        );
        rep.ratio("fastpath_components_per_batch", fast_per_batch);
    }

    // single-bottleneck fast path vs the general waterfill: 8 disjoint
    // 32-to-1 incasts, the equal-share shape the fast path targets. The
    // two paths must agree bit-for-bit before either is timed; the gated
    // ratio is their time quotient on identical work.
    {
        let mut router = Router::with_seed(&small, 41);
        let nics = small.cfg.compute_endpoints() as u32;
        let mut flows: Vec<RoutedFlow> = Vec::new();
        for r in 0..8u32 {
            let root = (r * 512 + 9) % nics;
            for i in 0..32u32 {
                let src = (root + 16 + i * 13) % nics;
                // staggered sizes: completions thin the component one
                // flow at a time, so every shrink re-solves (fast-pathed
                // when enabled) instead of one simultaneous finish
                let bytes = (4 << 20) + (i as u64) * (1 << 16);
                let f = Flow::new(src, root, bytes);
                flows.push(RoutedFlow { path: router.route(&f), flow: f });
            }
        }
        let fast_opts = DesOpts::default(); // fast path on by default
        let gen_opts = DesOpts {
            single_bottleneck_fastpath: false,
            ..DesOpts::default()
        };
        let rf = DesSim::new(&small, fast_opts.clone())
            .run_simultaneous(&flows);
        let rg = DesSim::new(&small, gen_opts.clone())
            .run_simultaneous(&flows);
        assert_eq!(
            rf.finish.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            rg.finish.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "fast path must be bit-identical to the general waterfill"
        );
        assert!(
            rf.fastpath_components > 0 && rg.fastpath_components == 0,
            "incast components must take the fast path when enabled"
        );
        let fast = rep.timed(
            "des_single_bottleneck_fastpath",
            "des/single-bottleneck fast path (8x32 incast)",
            10,
            || {
                let sim = DesSim::new(&small, fast_opts.clone());
                std::hint::black_box(sim.run_simultaneous(&flows));
            },
        );
        let general = rep.timed(
            "des_single_bottleneck_fastpath_general",
            "des/single-bottleneck general   (8x32 incast)",
            10,
            || {
                let sim = DesSim::new(&small, gen_opts.clone());
                std::hint::black_box(sim.run_simultaneous(&flows));
            },
        );
        rep.ratio("fastpath_speedup", general / fast);
    }

    // dense router load map vs the hash baseline on full-Aurora paths:
    // the adaptive router's per-decision load reads/writes are the hot
    // loop this store replaced (EXPERIMENTS.md §Raw speed)
    {
        use aurorasim::fabric::{LoadMap, SparseLoadMap};
        let flows = random_flows(&aurora, 1000, 43);
        let mut dense = LoadMap::new(&aurora);
        let mut sparse = SparseLoadMap::new();
        let d = rep.timed(
            "des_router_dense_load",
            "load/dense router map (1k aurora paths)",
            50,
            || {
                dense.clear();
                for rf in &flows {
                    dense.add_path(&rf.path.links, rf.flow.bytes as f64);
                }
                let mut acc = 0.0;
                for rf in &flows {
                    acc += dense.max_on(&rf.path.links)
                        + dense.sum_on(&rf.path.links);
                }
                std::hint::black_box(acc);
            },
        );
        let h = rep.timed(
            "des_router_dense_load_hash",
            "load/hash router map  (1k aurora paths)",
            50,
            || {
                sparse.clear();
                for rf in &flows {
                    sparse.add_path(&rf.path.links, rf.flow.bytes as f64);
                }
                let mut acc = 0.0;
                for rf in &flows {
                    acc += sparse.max_on(&rf.path.links)
                        + sparse.sum_on(&rf.path.links);
                }
                std::hint::black_box(acc);
            },
        );
        rep.ratio("dense_load_speedup", h / d);
    }

    // persistent worker pool vs per-batch thread spawn: the DES fans out
    // thousands of small component batches per run, so dispatch overhead
    // is the cost that matters — same items, same worker count, fresh
    // `thread::spawn` per batch vs parked workers woken by condvar
    {
        use aurorasim::campaign::pool::{self, WorkerPool};
        let items: Vec<u64> = (0..64).collect();
        let threads = 4usize;
        let mut scratches: Vec<u64> = Vec::new();
        let work = |&x: &u64, s: &mut u64| {
            let mut acc = x;
            for i in 0..2_000u64 {
                acc = acc.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(7)
                    ^ i;
            }
            *s = acc;
            acc
        };
        let fresh = rep.timed(
            "pool_batch_fresh_spawn",
            "pool/64-item batch, fresh threads",
            200,
            || {
                std::hint::black_box(pool::par_map_pooled(
                    &items,
                    threads,
                    &mut scratches,
                    work,
                ));
            },
        );
        let wp = WorkerPool::new(threads);
        let persistent = rep.timed(
            "pool_batch_persistent",
            "pool/64-item batch, persistent pool",
            200,
            || {
                std::hint::black_box(pool::par_map_on(
                    &wp,
                    &items,
                    threads,
                    &mut scratches,
                    work,
                ));
            },
        );
        rep.ratio("pool_persistent_speedup", fresh / persistent);
    }

    // open-loop service tier: one million Poisson RPC arrivals streamed
    // over the full-Aurora machine at bounded memory (ROADMAP item 2).
    // The gated ratio is machine-independent: total materialized nodes
    // over peak live nodes — the streaming executor must retire flows
    // as they complete, so memory scales with peak concurrency (offered
    // load x latency), not trace length. A 10k-arrival run of the same
    // process pins the flat-peak contract: 100x the arrivals must not
    // grow the live window beyond concurrency warm-up noise.
    {
        use aurorasim::fabric::arrivals::{
            run_open_loop, PoissonArrivals, RpcClass,
        };
        use aurorasim::fabric::DesScratch;
        let full = Topology::new(&AuroraConfig::full_aurora());
        let nics = workload::spread_nics(&full, 2048);
        let mix = vec![
            RpcClass { bytes: 4 << 10, weight: 0.70 },
            RpcClass { bytes: 64 << 10, weight: 0.25 },
            RpcClass { bytes: 1 << 20, weight: 0.05 },
        ];
        let sim = DesSim::new(&full, DesOpts::default());
        let mut scratch = DesScratch::new();
        let run = |n: u64, scratch: &mut DesScratch| {
            let mut router = Router::with_seed(&full, 53);
            let src = PoissonArrivals::new(
                53,
                400_000.0,
                n,
                nics.clone(),
                mix.clone(),
            );
            run_open_loop(&sim, scratch, src, &mut router, 1e-3, 100e-3)
        };
        let (small_res, _) = run(10_000, &mut scratch); // also the warmup
        let t0 = Instant::now();
        let (res, ss) = run(1_000_000, &mut scratch);
        let dt = t0.elapsed().as_secs_f64();
        rep.record(
            "des_open_loop_steady",
            "des/open-loop steady 1M arrivals (full aurora)",
            dt,
        );
        assert_eq!(res.late_releases, 0, "arrival floors are never late");
        assert_eq!(ss.completed, 1_000_000, "every arrival must retire");
        assert!(
            res.peak_live_nodes <= small_res.peak_live_nodes * 4,
            "100x arrivals must keep the live window flat \
             (peak {} at 1M vs {} at 10k)",
            res.peak_live_nodes,
            small_res.peak_live_nodes
        );
        let headroom = res.total_nodes as f64 / res.peak_live_nodes as f64;
        println!(
            "des/open-loop live-node headroom (1M)            {headroom:>10.1}x \
             (peak {} of {}, p99 {:.3} ms)",
            res.peak_live_nodes,
            res.total_nodes,
            ss.p99 * 1e3
        );
        rep.ratio("open_loop_live_headroom", headroom);
    }

    // graceful-degradation hook overhead: the same open-loop service run
    // priced with no policy vs an armed-but-inert ServicePolicy (every
    // knob off). Arming turns on the whole degradation path — per-class
    // tagging, the admission check per arrival, the budget plumbing —
    // but an inert policy schedules no EV_DEADLINE/EV_HEDGE events and
    // sheds nothing, so the two runs must agree bit-for-bit and the
    // gated ratio (no-policy time / armed time, floor 0.95) bounds the
    // bookkeeping cost of carrying a policy at ~5%.
    {
        use aurorasim::fabric::arrivals::{
            run_open_loop, PoissonArrivals, RpcClass,
        };
        use aurorasim::fabric::{ClassPolicy, DesScratch, ServicePolicy};
        let nics = workload::spread_nics(&small, 64);
        let mix = vec![
            RpcClass { bytes: 4 << 10, weight: 0.70 },
            RpcClass { bytes: 64 << 10, weight: 0.25 },
            RpcClass { bytes: 1 << 20, weight: 0.05 },
        ];
        let inert = ServicePolicy::uniform(mix.len(), ClassPolicy::OFF);
        assert!(inert.is_inert());
        let sim_none = DesSim::new(&small, DesOpts::default());
        let sim_armed = DesSim::new(
            &small,
            DesOpts { policies: Some(inert), ..DesOpts::default() },
        );
        let mut scratch = DesScratch::new();
        let run = |sim: &DesSim, scratch: &mut DesScratch| {
            let mut router = Router::with_seed(&small, 71);
            let src = PoissonArrivals::new(
                71,
                80_000.0,
                40_000,
                nics.clone(),
                mix.clone(),
            );
            run_open_loop(sim, scratch, src, &mut router, 1e-3, 25e-3)
        };
        let (rn, sn) = run(&sim_none, &mut scratch); // also the warmup
        let (ra, sa) = run(&sim_armed, &mut scratch);
        assert_eq!(
            (sn.p50.to_bits(), sn.p99.to_bits(), rn.makespan.to_bits()),
            (sa.p50.to_bits(), sa.p99.to_bits(), ra.makespan.to_bits()),
            "an armed-but-inert service policy must not perturb results"
        );
        assert_eq!(sn.completed, sa.completed);
        assert!(sa.shed.iter().all(|&v| v == 0));
        assert_eq!(ra.abandoned_flows + ra.hedged_flows, 0);
        let none = rep.timed(
            "des_open_loop_no_policy",
            "des/open-loop 40k arrivals, no service policy",
            3,
            || {
                std::hint::black_box(run(&sim_none, &mut scratch));
            },
        );
        let armed = rep.timed(
            "des_open_loop_policy_armed",
            "des/open-loop 40k arrivals, inert policy armed",
            3,
            || {
                std::hint::black_box(run(&sim_armed, &mut scratch));
            },
        );
        println!(
            "des/degrade hook overhead (armed/none)           {:>10.2}x",
            armed / none
        );
        rep.ratio("degrade_overhead", none / armed);
    }

    // incast + congestion classification
    let mut router = Router::new(&small);
    let incast: Vec<RoutedFlow> = (0..64)
        .map(|i| {
            let f = Flow::new((i * 8) as u32, 500, 4 << 20);
            RoutedFlow { path: router.route(&f), flow: f }
        })
        .collect();
    rep.timed("des_incast_64", "des/incast-64-to-1 (congestion mgmt)", 10,
        || {
            let sim = DesSim::new(&small, DesOpts::default());
            std::hint::black_box(sim.run_simultaneous(&incast));
        });

    // analytic tier at full machine scale
    let cfg = AuroraConfig::aurora();
    rep.timed(
        "analytic_alltoall_9658",
        "analytic/alltoall 9658 nodes (per point)",
        10_000,
        || {
            std::hint::black_box(
                aurorasim::fabric::analytic::alltoall_aggregate_bw(
                    &cfg, 9658, 16, 1 << 20));
        },
    );

    if let Some(path) = std::env::var_os("BENCH_JSON") {
        let text = rep.to_json().dump_pretty();
        std::fs::write(&path, text).expect("write BENCH_JSON");
        println!("bench report written to {}", path.to_string_lossy());
    }
}
