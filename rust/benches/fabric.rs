//! Fabric-simulator hot-path benchmarks: adaptive routing throughput, the
//! max-min DES solver, round evaluation at scale. These are the L3 paths
//! the §Perf pass optimizes (see EXPERIMENTS.md §Perf).
//!
//! Hand-rolled harness (offline build — no criterion): prints
//! `name: time/iter` rows; `cargo bench` runs it.

use aurorasim::config::AuroraConfig;
use aurorasim::fabric::des::{DesOpts, DesSim};
use aurorasim::fabric::rounds::CostModel;
use aurorasim::fabric::{Flow, RoutedFlow, Router};
use aurorasim::topology::Topology;
use aurorasim::util::Pcg;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, f: F) {
    timed(name, iters, f);
}

/// Like `bench` but returns seconds/iter so callers can report ratios.
fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters.div_ceil(10).min(3) {
        f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<48} {:>12.3} us/iter  ({iters} iters)", per * 1e6);
    per
}

fn random_flows(topo: &Topology, n: usize, seed: u64) -> Vec<RoutedFlow> {
    let mut rng = Pcg::new(seed);
    let mut router = Router::with_seed(topo, seed);
    let nics = topo.cfg.compute_endpoints() as u64;
    (0..n)
        .map(|_| {
            let src = rng.gen_range(nics) as u32;
            let dst = (src + 1 + rng.gen_range(nics - 1) as u32) % nics as u32;
            let f = Flow::new(src, dst, 1 << 20);
            RoutedFlow { path: router.route(&f), flow: f }
        })
        .collect()
}

fn main() {
    println!("== fabric benches ==");
    let aurora = Topology::new(&AuroraConfig::aurora());
    let small = Topology::new(&AuroraConfig::small(16, 16));

    // routing on the full 84,992-NIC machine
    bench("route/aurora (1k flows, adaptive)", 20, || {
        let mut router = Router::with_seed(&aurora, 7);
        let mut rng = Pcg::new(9);
        for _ in 0..1000 {
            let src = rng.gen_range(84_992) as u32;
            let dst = (src + 4096) % 84_992;
            std::hint::black_box(router.route(&Flow::new(src, dst, 65536)));
        }
    });

    // round evaluation at three sizes
    for n in [100usize, 1_000, 10_000] {
        let flows = random_flows(&aurora, n, 11);
        let cm = CostModel::new(&aurora);
        bench(&format!("eval_round/aurora ({n} flows)"),
              if n >= 10_000 { 5 } else { 30 }, || {
            std::hint::black_box(cm.eval_round(&flows));
        });
    }

    // DES: incremental component solver vs the dense full-recompute
    // oracle (EXPERIMENTS.md §Perf; acceptance: >= 5x at 2048 flows).
    // The oracle is skipped at 8192 unless BENCH_ORACLE_8192=1 — it is
    // O(events x flows x links) and takes minutes there.
    for n in [32usize, 128, 512, 2048, 8192] {
        let flows = random_flows(&small, n, 13);
        let iters = match n {
            0..=128 => 10,
            129..=512 => 3,
            _ => 1,
        };
        let inc = timed(&format!("des/incremental ({n} flows)"), iters, || {
            let sim = DesSim::new(&small, DesOpts::default());
            std::hint::black_box(sim.run_simultaneous(&flows));
        });
        let run_oracle =
            n < 8192 || std::env::var_os("BENCH_ORACLE_8192").is_some();
        if run_oracle {
            let ora = timed(&format!("des/oracle      ({n} flows)"), iters,
                || {
                    let sim = DesSim::new(&small, DesOpts::default());
                    std::hint::black_box(sim.run_simultaneous_oracle(&flows));
                });
            println!(
                "des/speedup     ({n} flows)                      {:>10.1}x",
                ora / inc
            );
        }
    }

    // incast + congestion classification
    let mut router = Router::new(&small);
    let incast: Vec<RoutedFlow> = (0..64)
        .map(|i| {
            let f = Flow::new((i * 8) as u32, 500, 4 << 20);
            RoutedFlow { path: router.route(&f), flow: f }
        })
        .collect();
    bench("des/incast-64-to-1 (congestion mgmt)", 10, || {
        let sim = DesSim::new(&small, DesOpts::default());
        std::hint::black_box(sim.run_simultaneous(&incast));
    });

    // analytic tier at full machine scale
    let cfg = AuroraConfig::aurora();
    bench("analytic/alltoall 9658 nodes (per point)", 10_000, || {
        std::hint::black_box(
            aurorasim::fabric::analytic::alltoall_aggregate_bw(
                &cfg, 9658, 16, 1 << 20));
    });
}
