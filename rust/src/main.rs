//! `repro` — the AuroraSim command-line interface.
//!
//! ```text
//! repro spec                         print Table 1 (machine model)
//! repro list                         list experiment ids
//! repro reproduce <id>|all           regenerate a paper table/figure
//! repro functional [dir]             PJRT end-to-end validations
//! repro validate [nodes]             fabric-validation ladder demo
//! repro launch <nodes> <ppn> <app>   run a benchmark via the launcher
//! repro campaign [threads] [out]     parallel scenario sweep (JSON report)
//! repro openloop [threads] [out]     1M-arrival open-loop service run
//! repro chaos [threads] [out]        fault-rate x policy chaos sweep
//! repro brownout [threads] [out]     fault-rate x overload-policy sweep
//! repro lint [scenario|--all]        pre-execution workload verifier
//! ```
//!
//! (The registry is offline in this environment, so argument parsing is
//! hand-rolled — no clap.)

use anyhow::{bail, Result};
use aurorasim::campaign::{pool, Campaign};
use aurorasim::config::AuroraConfig;
use aurorasim::coordinator::{JobSpec, Launcher};
use aurorasim::machine::Machine;
use aurorasim::mpi::{coll, Comm};
use aurorasim::reproduce;
use aurorasim::runtime::Runtime;
use aurorasim::validate::{NodeFault, Validator};

fn usage() -> ! {
    eprintln!(
        "usage: repro \
         <spec|list|reproduce|functional|validate|launch|campaign|openloop\
         |chaos|brownout|lint> ..."
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    match cmd {
        "spec" => {
            println!("{}", Machine::aurora().spec_table());
        }
        "list" => {
            for id in reproduce::all_ids() {
                println!("{id}");
            }
        }
        "reproduce" => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            if id == "all" {
                for id in reproduce::all_ids() {
                    println!("{}", reproduce::run(id)?);
                }
            } else {
                println!("{}", reproduce::run(id)?);
            }
        }
        "functional" => {
            let dir = args.get(1).map(String::as_str).unwrap_or("artifacts");
            let mut rt = Runtime::open(dir)?;
            println!("PJRT platform: {}", rt.platform());
            println!("{}", reproduce::functional_suite(&mut rt)?);
        }
        "validate" => {
            let nodes: usize = args
                .get(1)
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(64);
            let m = Machine::new(&AuroraConfig::small(8, 4));
            let mut v = Validator::new(&m);
            // inject a couple of faults so the ladder has work to do
            v.inject(3, NodeFault { perf_factor: 0.5, ..Default::default() });
            v.inject(9, NodeFault { hw_errors: 3, ..Default::default() });
            let all: Vec<usize> =
                (0..nodes.min(m.cfg.nodes())).collect();
            for rep in v.systematic(&all) {
                println!(
                    "level {:?}: tested {} failed {:?}",
                    rep.level, rep.tested_nodes, rep.failed_nodes
                );
            }
            let restored = v.repair_and_revalidate();
            println!("repaired + revalidated: {restored:?}");
        }
        "launch" => {
            if args.len() < 4 {
                usage();
            }
            let nodes: usize = args[1].parse()?;
            let ppn: usize = args[2].parse()?;
            let app = args[3].as_str();
            let m = Machine::new(&AuroraConfig::small(8, 4));
            let mut l = Launcher::new(&m);
            let spec = JobSpec::new(app, nodes, ppn);
            match app {
                "allreduce" => {
                    let rep = l.launch(&spec, |w| {
                        coll::allreduce(w, &Comm::world(nodes * ppn), 1 << 20)
                    })?;
                    println!(
                        "allreduce(1MiB) on {nodes}x{ppn}: {:.1} us",
                        rep.result * 1e6
                    );
                    println!("{}", rep.mpich_summary);
                    println!("{}", rep.counter_report);
                }
                "alltoall" => {
                    let rep = l.launch(&spec, |w| {
                        coll::alltoall(w, &Comm::world(nodes * ppn), 64 << 10)
                    })?;
                    println!(
                        "alltoall(64KiB) on {nodes}x{ppn}: {:.3} ms",
                        rep.result * 1e3
                    );
                    println!("{}", rep.mpich_summary);
                }
                "barrier" => {
                    let rep = l.launch(&spec, |w| {
                        coll::barrier(w, &Comm::world(nodes * ppn))
                    })?;
                    println!(
                        "barrier on {nodes}x{ppn}: {:.1} us",
                        rep.result * 1e6
                    );
                }
                _ => bail!("unknown app '{app}' (allreduce|alltoall|barrier)"),
            }
        }
        "campaign" => {
            // repro campaign [threads] [out.json] — the standard scenario
            // sweep through the launcher's prolog/epilog gates.
            // DES_THREADS=<n> fans each scenario's per-batch component
            // solves over n solver threads; reports are byte-identical at
            // every value (the CI solver-thread matrix diffs them).
            let threads: usize = args
                .get(1)
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or_else(pool::default_threads);
            let cfg = AuroraConfig::small(8, 4);
            let m = Machine::new(&cfg);
            let mut l = Launcher::new(&m);
            let mut c =
                Campaign::standard(&cfg, aurorasim::reproduce::CAMPAIGN_SEED);
            if let Some(n) = std::env::var("DES_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
            {
                for s in &mut c.scenarios {
                    s.opts.solver_threads = n.max(1);
                }
            }
            let (rep, offlined) = l.launch_campaign(&c, threads)?;
            println!("{}", rep.render_table());
            if !offlined.is_empty() {
                println!("epilog offlined nodes: {offlined:?}");
            }
            if let Some(out) = args.get(2) {
                rep.write(out)?;
                println!("report written to {out}");
            }
        }
        "openloop" => {
            // repro openloop [threads] [out.json] — one million Poisson
            // RPC arrivals streamed over the full-Aurora topology at
            // bounded memory (ROADMAP item 2). DES_THREADS=<n> fans the
            // per-batch component solves over n solver threads; the CI
            // campaign-determinism job byte-diffs the report across
            // serial and DES_THREADS=8 runs.
            let threads: usize = args
                .get(1)
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or_else(pool::default_threads);
            let mut c = Campaign::open_loop_aurora(
                aurorasim::reproduce::CAMPAIGN_SEED,
            );
            if let Some(n) = std::env::var("DES_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
            {
                for s in &mut c.scenarios {
                    s.opts.solver_threads = n.max(1);
                }
            }
            let rep = c.run(threads);
            println!("{}", rep.render_table());
            if let Some(out) = args.get(2) {
                rep.write(out)?;
                println!("report written to {out}");
            }
        }
        "chaos" => {
            // repro chaos [threads] [out.json] — the fault-injection
            // sweep: fault rate (flap count over a fixed horizon) x
            // recovery policy (reroute / retry-backoff / abort) on the
            // multi-group halo+allreduce step. Every cell's fault
            // schedule is derived from the campaign seed and the cell
            // name, so the report is deterministic; the CI
            // campaign-determinism job byte-diffs it across
            // DES_THREADS=1 and DES_THREADS=8.
            let threads: usize = args
                .get(1)
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or_else(pool::default_threads);
            let cfg = AuroraConfig::small(4, 4);
            let mut c =
                Campaign::chaos(&cfg, aurorasim::reproduce::CAMPAIGN_SEED);
            if let Some(n) = std::env::var("DES_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
            {
                for s in &mut c.scenarios {
                    s.opts.solver_threads = n.max(1);
                }
            }
            let rep = c.run(threads);
            println!("{}", rep.render_table());
            let failed: usize =
                rep.results.iter().map(|r| r.failed_flows).sum();
            let aborted: usize =
                rep.results.iter().map(|r| r.aborted_nodes).sum();
            println!(
                "chaos: {} scenario(s), {failed} failed flow(s), \
                 {aborted} aborted dag node(s)",
                rep.results.len()
            );
            if let Some(out) = args.get(2) {
                rep.write(out)?;
                println!("report written to {out}");
            }
        }
        "brownout" => {
            // repro brownout [threads] [out.json] — the graceful-
            // degradation sweep: fault rate (flap count over the service
            // run) x overload policy (off / shed / full) on the Poisson
            // RPC service. Each row's schema-v5 `degradation` block
            // carries the per-class shed/abandoned/failed/hedged
            // counters and the goodput the policy preserved; like chaos,
            // cell fault schedules are name-derived, so the report is
            // deterministic and the CI campaign-determinism job
            // byte-diffs it across DES_THREADS=1 and DES_THREADS=8.
            let threads: usize = args
                .get(1)
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or_else(pool::default_threads);
            let cfg = AuroraConfig::small(4, 4);
            let mut c =
                Campaign::brownout(&cfg, aurorasim::reproduce::CAMPAIGN_SEED);
            if let Some(n) = std::env::var("DES_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
            {
                for s in &mut c.scenarios {
                    s.opts.solver_threads = n.max(1);
                }
            }
            let rep = c.run(threads);
            println!("{}", rep.render_table());
            for r in &rep.results {
                if let (Some(ss), true) =
                    (&r.steady_state, r.policy.is_some())
                {
                    let shed: u64 = ss.shed.iter().sum();
                    let abandoned: u64 = ss.abandoned.iter().sum();
                    let failed: u64 = ss.failed.iter().sum();
                    println!(
                        "{:28} shed {shed:>6}  abandoned {abandoned:>6}  \
                         failed {failed:>6}  goodput {:.0}/s",
                        r.name, ss.goodput_flows
                    );
                }
            }
            if let Some(out) = args.get(2) {
                rep.write(out)?;
                println!("report written to {out}");
            }
        }
        "lint" => {
            // repro lint [scenario|--all] — run the pre-execution
            // workload verifier (fabric::analysis) over every campaign
            // scenario without executing any of them: closed-loop DAGs
            // are fully materialized and checked, open-loop services
            // stream a 64-window prefix through the round-source
            // liveness checks. Exits nonzero if any scenario's workload
            // carries a structural error.
            let target = args.get(1).map(String::as_str).unwrap_or("--all");
            let seed = aurorasim::reproduce::CAMPAIGN_SEED;
            let mut scenarios =
                Campaign::standard(&AuroraConfig::small(8, 4), seed)
                    .scenarios;
            scenarios.extend(Campaign::open_loop_aurora(seed).scenarios);
            // the brownout sweep's service policies go through the same
            // verifier (analyze_policies) as its workloads
            scenarios.extend(
                Campaign::brownout(&AuroraConfig::small(4, 4), seed)
                    .scenarios,
            );
            if target != "--all" {
                scenarios.retain(|s| s.name == target);
                if scenarios.is_empty() {
                    bail!(
                        "unknown scenario '{target}' \
                         (run `repro lint --all` for the full sweep)"
                    );
                }
            }
            let mut errors = 0usize;
            for s in &scenarios {
                let topo = aurorasim::topology::Topology::new(&s.cfg);
                let rep = s.lint(&topo, 64);
                println!(
                    "{:32} {:>7} nodes {:>5} rounds  {} error(s), \
                     {} warning(s)",
                    s.name,
                    rep.nodes,
                    rep.rounds,
                    rep.errors(),
                    rep.warnings()
                );
                if !rep.diags.is_empty() {
                    for line in
                        rep.render().lines().take(rep.diags.len())
                    {
                        println!("    {line}");
                    }
                }
                errors += rep.errors();
            }
            if errors > 0 {
                bail!("lint: {errors} workload error(s)");
            }
            println!("lint: {} scenario(s), no errors", scenarios.len());
        }
        _ => usage(),
    }
    Ok(())
}
