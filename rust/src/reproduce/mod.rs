//! The reproduction harness: regenerates every table and figure of the
//! paper's evaluation (§5 + the §3.8 validation figures), printing
//! paper-reported vs model-measured values side by side.
//!
//! `repro reproduce <id>` runs one experiment; `repro reproduce all` runs
//! the lot (EXPERIMENTS.md is generated from this output). Functional
//! (PJRT-artifact) validations live in [`functional_suite`] and need
//! `make artifacts` first.

use crate::apps;
use crate::config::AuroraConfig;
use crate::machine::Machine;
use crate::metrics::{fmt_bw, fmt_flops, fmt_time, table};
use crate::mpi::rma::RmaKind;
use crate::runtime::Runtime;
use anyhow::{bail, Result};

/// All experiment ids, in paper order.
pub const ALL: [&str; 17] = [
    "table1", "fig4", "fig5", "fig6", "fig7", "fig10", "fig11", "fig12",
    "fig13", "fig14", "fig15", "table2", "fig16", "graph500", "hpcg",
    "fig17", "fig18",
];
/// ...continued (kept in two arrays to document the §5.3 block).
/// `campaign` runs the standard multi-scenario sweep through the
/// campaign engine (see [`crate::campaign`]).
pub const ALL2: [&str; 5] = ["fig19", "fig20", "table5", "table6", "campaign"];

pub fn all_ids() -> Vec<&'static str> {
    ALL.iter().chain(ALL2.iter()).copied().collect()
}

/// Run one experiment by id.
pub fn run(id: &str) -> Result<String> {
    let aurora = AuroraConfig::aurora();
    Ok(match id {
        "table1" => table1(&aurora),
        "fig4" => fig4(&aurora),
        "fig5" => fig5(),
        "fig6" => fig6(&aurora),
        "fig7" => fig7(&aurora),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "fig13" => fig13(),
        "fig14" => fig14(),
        "fig15" => fig15(&aurora),
        "table2" => table2(&aurora),
        "fig16" => fig16(&aurora),
        "graph500" => graph500(&aurora),
        "hpcg" => hpcg(&aurora),
        "fig17" => fig17(&aurora),
        "fig18" => fig18(&aurora),
        "fig19" => fig19(&aurora),
        "fig20" => fig20(&aurora),
        "table5" => fmm_table(RmaKind::Get),
        "table6" => fmm_table(RmaKind::Put),
        "campaign" => campaign_experiment(),
        _ => bail!("unknown experiment '{id}' (see `repro list`)"),
    })
}

fn header(title: &str, paper: &str) -> String {
    format!("== {title}\n   paper: {paper}\n")
}

fn table1(cfg: &AuroraConfig) -> String {
    let m = Machine::new(cfg);
    let mut s = header(
        "Table 1 — Aurora aggregate specifications",
        "10,624 nodes / 21,248 CPUs / 63,744 GPUs / 2.12 PB/s injection \
         / 1.37 PB/s global",
    );
    s.push_str(&m.spec_table());
    s.push('\n');
    s
}

fn fig4(cfg: &AuroraConfig) -> String {
    let a2a = apps::alltoall::Alltoall::paper();
    let mut rows = Vec::new();
    for p in a2a.sweep(cfg, &apps::alltoall::Alltoall::default_sizes()) {
        rows.push(vec![
            format!("{}", p.msg_bytes),
            fmt_bw(p.aggregate_bw),
        ]);
    }
    let peak = a2a.peak(cfg);
    let mut s = header(
        "Fig 4 — all2all fabric validation, 9,658 nodes x PPN 16",
        "smooth rise with transfer size, peak aggregate 228.92 TB/s",
    );
    s.push_str(&table(&["msg bytes", "aggregate BW"], &rows));
    s.push_str(&format!("measured peak: {}\n", fmt_bw(peak)));
    s
}

fn fig5() -> String {
    let m = Machine::new(&AuroraConfig::small(8, 4));
    let rep = apps::gpcnet::Gpcnet::default().run(&m, true);
    let mut s = header(
        "Fig 5 — GPCNet network load test (reduced scale, congestion mgmt on)",
        "isolated RR lat 3.1/5.2 us (avg/99%); CIF: lat 2.3x/10.6x, \
         BW 1.5x/1.0x",
    );
    s.push_str(&table(
        &["metric", "isolated", "congested", "impact"],
        &[
            vec![
                "RR two-sided lat avg".into(),
                fmt_time(rep.rr_lat_isolated.0),
                fmt_time(rep.rr_lat_congested.0),
                format!("{:.1}x", rep.cif_lat.0),
            ],
            vec![
                "RR two-sided lat 99%".into(),
                fmt_time(rep.rr_lat_isolated.1),
                fmt_time(rep.rr_lat_congested.1),
                format!("{:.1}x", rep.cif_lat.1),
            ],
            vec![
                "RR BW+Sync avg/rank".into(),
                fmt_bw(rep.rr_bw_isolated.0),
                fmt_bw(rep.rr_bw_congested.0),
                format!("{:.1}x", rep.cif_bw.0),
            ],
        ],
    ));
    s
}

fn fig6(cfg: &AuroraConfig) -> String {
    let mut s = header(
        "Fig 6 — osu_mbw_mr at 10,262 nodes (41,048 pairs, PPN 8)",
        "aggregate bandwidth saturating with message size",
    );
    let mut rows = Vec::new();
    for p in [1u64 << 10, 1 << 14, 1 << 17, 1 << 20] {
        rows.push(vec![
            format!("{p}"),
            fmt_bw(apps::osu::mbw_mr(cfg, 10_262, 8, p)),
        ]);
    }
    s.push_str(&table(&["msg bytes", "aggregate BW"], &rows));
    s
}

fn fig7(cfg: &AuroraConfig) -> String {
    let mut s = header(
        "Fig 7 — osu_mbw_mr across node counts and PPN",
        "bandwidth grows with PPN; NIC sharing beyond PPN 8",
    );
    let mut rows = Vec::new();
    for nodes in [16usize, 64, 256, 1024] {
        for ppn in [1usize, 2, 4, 8, 16] {
            rows.push(vec![
                nodes.to_string(),
                ppn.to_string(),
                fmt_bw(apps::osu::mbw_mr(cfg, nodes, ppn, 1 << 20)),
            ]);
        }
    }
    s.push_str(&table(&["nodes", "PPN", "aggregate BW"], &rows));
    s
}

fn fig10() -> String {
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let sizes: Vec<u64> = (0..=20).map(|p| 1u64 << p).collect();
    let pts = apps::osu::p2p_latency_sweep(&m, &sizes);
    let mut s = header(
        "Fig 10 — p2p latency vs message size (16-msg window, host buffers)",
        "flat ~2-3 us to 64 B; jump at 128 B (NIC SRAM -> host DRAM); \
         bandwidth regime beyond",
    );
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|(b, l)| vec![b.to_string(), fmt_time(*l)])
        .collect();
    s.push_str(&table(&["msg bytes", "latency"], &rows));
    s
}

fn fig11() -> String {
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let mut s = header(
        "Fig 11 — off-socket aggregate bandwidth vs ranks/socket (host)",
        "linear to 4 ranks (1/NIC); 2 ranks/NIC reach ~90 GB/s/socket",
    );
    let rows: Vec<Vec<String>> = [1usize, 2, 3, 4, 6, 8]
        .iter()
        .map(|&r| {
            vec![
                r.to_string(),
                fmt_bw(apps::osu::socket_bandwidth(&m, r, false)),
            ]
        })
        .collect();
    s.push_str(&table(&["ranks/socket", "aggregate BW"], &rows));
    s
}

fn fig12() -> String {
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let mut s = header(
        "Fig 12 — GPU-buffer bandwidth, processes sharing one NIC",
        "single process cannot saturate; ~23 GB/s effective at 256 KB \
         with multiple processes",
    );
    let mut rows = Vec::new();
    for ranks in [1usize, 2, 4] {
        for msg in [64u64 << 10, 256 << 10, 1 << 20] {
            rows.push(vec![
                ranks.to_string(),
                msg.to_string(),
                fmt_bw(apps::osu::single_nic_gpu_bw(&m, ranks, msg)),
            ]);
        }
    }
    s.push_str(&table(&["ranks", "msg bytes", "BW"], &rows));
    s
}

fn fig13() -> String {
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let mut s = header(
        "Fig 13 — single-socket aggregate GPU-buffer bandwidth",
        "~70 GB/s (PCIe Gen4<->Gen5 conversion) vs ~90 GB/s host",
    );
    let rows: Vec<Vec<String>> = [2usize, 4, 8]
        .iter()
        .map(|&r| {
            vec![
                r.to_string(),
                fmt_bw(apps::osu::socket_bandwidth(&m, r, true)),
                fmt_bw(apps::osu::socket_bandwidth(&m, r, false)),
            ]
        })
        .collect();
    s.push_str(&table(&["ranks/socket", "GPU BW", "host BW"], &rows));
    s
}

fn fig14() -> String {
    // 2,048-node dragonfly with Aurora constants
    let m = Machine::new(&AuroraConfig::small(32, 32));
    let nodes = apps::allreduce::fig14_nodes(&m);
    let sizes = apps::allreduce::fig14_sizes();
    let pts = apps::allreduce::sweep(&m, &nodes, &sizes);
    let mut s = header(
        "Fig 14 — MPI_Allreduce latency vs node count (GPU buffers)",
        "sub-linear growth (recursive doubling); ring->tree switch \
         visible across sizes; up to 2,048 nodes",
    );
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.nodes.to_string(),
                p.msg_bytes.to_string(),
                fmt_time(p.latency),
                p.algorithm.into(),
            ]
        })
        .collect();
    s.push_str(&table(&["nodes", "msg bytes", "latency", "algo"], &rows));
    s
}

fn fig15(cfg: &AuroraConfig) -> String {
    let run5439 = apps::hpl::performance(cfg, 5439);
    let run9234 = apps::hpl::performance(cfg, 9234);
    let mut s = header(
        "Fig 15 — HPL performance over time, 5,439 and 9,234 nodes",
        "smooth through LU; 585 PF/s and 1.012 EF/s sustained; 4h21m54s",
    );
    for run in [&run5439, &run9234] {
        s.push_str(&format!(
            "{} nodes: N={}, P x Q = {} x {}, sustained {}, runtime {}\n",
            run.nodes,
            run.n,
            run.p,
            run.q,
            fmt_flops(run.rate),
            fmt_time(run.time)
        ));
        // sparse curve print
        let step = (run.curve.len() / 8).max(1);
        for c in run.curve.iter().step_by(step) {
            s.push_str(&format!(
                "   t={:>9} rate={}\n",
                fmt_time(c.t),
                fmt_flops(c.rate)
            ));
        }
    }
    s
}

fn table2(cfg: &AuroraConfig) -> String {
    let paper: [(usize, f64, f64); 9] = [
        (9234, 1012.0, 78.84),
        (8748, 954.43, 78.49),
        (8632, 949.02, 79.10),
        (8109, 873.78, 77.52),
        (8058, 865.93, 77.31),
        (7200, 805.24, 80.46),
        (6888, 764.04, 79.80),
        (6273, 688.99, 79.02),
        (5439, 585.43, 77.44),
    ];
    let mut s = header(
        "Table 2 — HPL scaling efficiency across node counts",
        "77.3% - 80.5% over 5,439..9,234 nodes",
    );
    let mut rows = Vec::new();
    for (nodes, ppf, peff) in paper {
        let run = apps::hpl::performance(cfg, nodes);
        rows.push(vec![
            nodes.to_string(),
            format!("{ppf:.0}"),
            format!("{:.0}", run.rate / 1e15),
            format!("{peff:.2}"),
            format!("{:.2}", run.efficiency * 100.0),
        ]);
    }
    s.push_str(&table(
        &["nodes", "paper PF/s", "model PF/s", "paper eff%", "model eff%"],
        &rows,
    ));
    s
}

fn fig16(cfg: &AuroraConfig) -> String {
    let run = apps::hpl_mxp::performance(cfg, 9500);
    let mut s = header(
        "Fig 16 — HPL-MxP with 9,500 nodes",
        "11.64 EF/s, #1 on the HPL-MxP list; uniform scaling, short IR tail",
    );
    s.push_str(&format!(
        "measured: {} over {} (factor {}, IR {})\n",
        fmt_flops(run.rate),
        fmt_time(run.time),
        fmt_time(run.factor_time),
        fmt_time(run.ir_time)
    ));
    s
}

fn graph500(cfg: &AuroraConfig) -> String {
    let run = apps::graph500::performance(cfg, 8192, 42);
    let mut s = header(
        "§5.2.3 — Graph500 BFS, scale 42, 8,192 nodes",
        "69,373 GTEPS",
    );
    s.push_str(&format!(
        "measured: {:.0} GTEPS (BFS time {})\n",
        run.gteps,
        fmt_time(run.bfs_time)
    ));
    s
}

fn hpcg(cfg: &AuroraConfig) -> String {
    let run = apps::hpcg::performance(cfg, 4096);
    let mut s = header(
        "§5.2.4 — HPCG, 4,096 nodes",
        "5.613 PF/s (3rd on the HPCG list)",
    );
    s.push_str(&format!(
        "measured: {:.3} PF/s ({:.1} GF/s/node)\n",
        run.pflops, run.per_node_gflops
    ));
    s
}

fn scaling_table(pts: &[apps::ScalingPoint], fom_name: &str) -> String {
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.nodes.to_string(),
                format!("{:.3}", p.fom),
                format!("{:.1}%", p.efficiency * 100.0),
            ]
        })
        .collect();
    table(&["nodes", fom_name, "efficiency"], &rows)
}

fn fig17(cfg: &AuroraConfig) -> String {
    let mut s = header(
        "Fig 17 + Table 3 — HACC weak scaling (PPN 96)",
        "99% efficiency at 1,024 nodes, 97% at 8,192 (grids 4608/9216/18432)",
    );
    s.push_str(&scaling_table(&apps::hacc::fig17(cfg), "step time (s)"));
    s
}

fn fig18(cfg: &AuroraConfig) -> String {
    let pts = apps::nekbone::fig18(cfg, &[128, 512, 2048, 4096]);
    let pts_pf: Vec<apps::ScalingPoint> = pts
        .iter()
        .map(|p| apps::ScalingPoint {
            nodes: p.nodes,
            fom: p.fom / 1e15,
            efficiency: p.efficiency,
        })
        .collect();
    let mut s = header(
        "Fig 18 — Nekbone weak scaling (PPN 12, 42k elems/rank, nx1 9 & 12)",
        ">95% parallel efficiency up to 4,096 nodes",
    );
    s.push_str(&scaling_table(&pts_pf, "PFLOP/s"));
    s
}

fn fig19(cfg: &AuroraConfig) -> String {
    let mut s = header(
        "Fig 19 — AMR-Wind weak scaling FOM (256^3 cells/rank, PPN 12)",
        "billions of cells/s growing to 8,192 nodes",
    );
    s.push_str(&scaling_table(
        &apps::amr_wind::fig19(cfg, &[128, 512, 2048, 4096, 8192]),
        "B cells/s",
    ));
    s
}

fn fig20(cfg: &AuroraConfig) -> String {
    let mut s = header(
        "Fig 20 — LAMMPS Rhodopsin weak scaling (254B atoms, PPN 96)",
        ">85% parallel efficiency at 9,216 nodes",
    );
    s.push_str(&scaling_table(
        &apps::lammps::fig20(cfg, &apps::lammps::FIG20_NODES),
        "step time (s)",
    ));
    s
}

fn fmm_table(kind: RmaKind) -> String {
    let m = Machine::new(&AuroraConfig::small(4, 8));
    let scale = 0.02;
    let (title, paper) = match kind {
        RmaKind::Get => (
            "Table 5 — FMM MPI_Get transfer time",
            "with HMEM: 0.9 / 1.1 / 1.6 / 14.5 s; without: 24.6 / 17.1 \
             / 13.0 s (9x16 NA)",
        ),
        RmaKind::Put => (
            "Table 6 — FMM MPI_Put transfer time",
            "with HMEM: 14.2 / 17.6 / 20.7 s; without: 28.4 / 38.9 / 49.7 s",
        ),
    };
    let mut s = header(title, paper);
    let with = apps::fmm::table(&m, kind, true, scale).unwrap();
    let without = apps::fmm::table(&m, kind, false, scale).unwrap();
    let mut rows = Vec::new();
    for (i, r) in with.iter().enumerate() {
        rows.push(vec![
            r.label.to_string(),
            format!("{:.1}", r.time),
            without
                .get(i)
                .map(|x| format!("{:.1}", x.time))
                .unwrap_or_else(|| "NA".into()),
        ]);
    }
    s.push_str(&table(&["config", "with HMEM (s)", "without HMEM (s)"],
                      &rows));
    s
}

/// Deterministic campaign seed shared by the reproduction harness, the
/// CLI default and the golden fixtures.
pub const CAMPAIGN_SEED: u64 = 0xA112a;

fn campaign_experiment() -> String {
    let cfg = AuroraConfig::small(8, 4);
    let c = crate::campaign::Campaign::standard(&cfg, CAMPAIGN_SEED);
    let rep = c.run(crate::campaign::pool::default_threads());
    let mut s = header(
        "Campaign — standard fabric scenario sweep (reduced scale)",
        "§3.8.2 GPCNet isolated/congested, §3.1 incast fan-ins, §3.4 \
         degraded lanes, §5.1 collective rounds, plus closed-loop \
         dependency-released rounds (collective-vs-incast, multi-job \
         phase stagger, HACC/AMR-Wind/LAMMPS step traces), the \
         open-loop Poisson RPC service scenarios (healthy and degraded) \
         and the mid-run fault-injection scenarios (link flap under \
         reroute, NIC outage under retry-backoff, random service flaps)",
    );
    s.push_str(&rep.render_table());
    s
}

/// Headline scalar per experiment, keyed for the golden regression
/// fixtures in `rust/tests/golden/` (tests/golden_reproduce.rs). Values
/// are model outputs, not paper numbers; the golden file pins them so a
/// perf refactor cannot silently shift what the reproduction reports.
pub fn key_metrics() -> Vec<(&'static str, f64)> {
    let cfg = AuroraConfig::aurora();
    let mut m: Vec<(&'static str, f64)> = Vec::new();
    let hpl_9234 = apps::hpl::performance(&cfg, 9234);
    m.push(("hpl_rate_9234", hpl_9234.rate));
    m.push(("hpl_efficiency_9234", hpl_9234.efficiency));
    m.push(("hpl_rate_5439", apps::hpl::performance(&cfg, 5439).rate));
    m.push(("hpl_mxp_rate_9500", apps::hpl_mxp::performance(&cfg, 9500).rate));
    m.push((
        "graph500_gteps_8192",
        apps::graph500::performance(&cfg, 8192, 42).gteps,
    ));
    m.push(("hpcg_pflops_4096", apps::hpcg::performance(&cfg, 4096).pflops));
    m.push((
        "alltoall_peak_bw",
        apps::alltoall::Alltoall::paper().peak(&cfg),
    ));
    m.push((
        "mbw_mr_10262x8_1m",
        apps::osu::mbw_mr(&cfg, 10_262, 8, 1 << 20),
    ));
    m.push(("hacc_eff_8192", apps::hacc::fig17(&cfg)[2].efficiency));
    m.push((
        "nekbone_eff_4096",
        apps::nekbone::fig18(&cfg, &[128, 4096])[1].efficiency,
    ));
    m.push((
        "lammps_eff_9216",
        apps::lammps::fig20(&cfg, &[128, 9216])[1].efficiency,
    ));
    // campaign scenarios: pin every makespan of the standard sweep
    let small = AuroraConfig::small(8, 4);
    let rep = crate::campaign::Campaign::standard(&small, CAMPAIGN_SEED)
        .run_serial();
    const CAMPAIGN_KEYS: [&str; 19] = [
        "campaign_gpcnet_isolated",
        "campaign_gpcnet_congested",
        "campaign_gpcnet_congested_nocm",
        "campaign_incast_8x16",
        "campaign_incast_8x16_nocm",
        "campaign_uniform_512",
        "campaign_permutation_256",
        "campaign_ring_256",
        "campaign_degraded_half_bw",
        "campaign_staggered_256",
        "campaign_coll_vs_incast",
        "campaign_phase_staggered_3job",
        "campaign_degraded_ring_closed",
        "campaign_hacc_step_closed",
        "campaign_amr_wind_step_closed",
        "campaign_lammps_step_closed",
        "campaign_halo_allreduce_closed",
        "campaign_open_loop_rpc",
        "campaign_open_loop_degraded",
    ];
    for (key, r) in CAMPAIGN_KEYS.iter().zip(&rep.results) {
        debug_assert_eq!(format!("campaign_{}", r.name).as_str(), *key);
        m.push((*key, r.makespan));
    }
    m
}

// ----------------------------------------------------------- functional

/// End-to-end functional validations through the PJRT artifacts.
pub fn functional_suite(rt: &mut Runtime) -> Result<String> {
    let m = Machine::new(&AuroraConfig::small(4, 4));
    let mut s = String::from("== Functional validation (PJRT artifacts)\n");

    let (resid, t) = apps::hpl::functional(rt, &m)?;
    s.push_str(&format!(
        "HPL distributed LU (N=256, 2x2 grid): scaled residual {resid:.3e} \
         ({}) sim time {}\n",
        if resid < 16.0 { "PASS < 16" } else { "FAIL" },
        fmt_time(t)
    ));
    anyhow::ensure!(resid < 16.0, "HPL residual check failed");

    let (r0, r1, iters, t) = apps::hpl_mxp::functional(rt, &m)?;
    s.push_str(&format!(
        "HPL-MxP IR: residual {r0:.3e} -> {r1:.3e} in {iters} FP64 IR \
         steps, sim time {}\n",
        fmt_time(t)
    ));
    anyhow::ensure!(r1 < 1e-8 * r0.max(1.0), "IR did not converge");

    let (r0, r1, iters, t) = apps::hpcg::functional(rt, &m, 25)?;
    s.push_str(&format!(
        "HPCG CG (8 ranks x 32^3): |r| {r0:.3e} -> {r1:.3e} in {iters} \
         iters, sim time {}\n",
        fmt_time(t)
    ));
    anyhow::ensure!(r1 < 0.1 * r0, "CG did not reduce residual");

    let (r0, r1, iters, t) = apps::nekbone::functional(rt, &m, 40)?;
    s.push_str(&format!(
        "Nekbone CG (32 elems, nx1=9): |r| {r0:.3e} -> {r1:.3e} in \
         {iters} iters, sim time {}\n",
        fmt_time(t)
    ));
    anyhow::ensure!(r1 < 0.1 * r0, "Nekbone CG did not reduce residual");

    let res = apps::graph500::functional(&m, 10, 8, 1);
    let ok = apps::graph500::validate_bfs(10, &res, 1);
    s.push_str(&format!(
        "Graph500 BFS (scale 10, 8 ranks): {} vertices, {} levels, \
         validation {}\n",
        res.visited,
        res.levels,
        if ok { "PASS" } else { "FAIL" }
    ));
    anyhow::ensure!(ok, "BFS validation failed");

    let (net, pmean) = apps::hacc::functional(rt, &m)?;
    s.push_str(&format!(
        "HACC: net-force ratio {net:.2e} (momentum), Poisson mean \
         {pmean:.2e}\n"
    ));

    let (ratio, _) = apps::lammps::functional(rt, &m)?;
    s.push_str(&format!("LAMMPS pair tile: net-force ratio {ratio:.2e}\n"));

    let (r0, r1) = apps::amr_wind::functional(rt, &m)?;
    s.push_str(&format!("AMR-Wind smoother: |r| {r0:.3e} -> {r1:.3e}\n"));
    anyhow::ensure!(r1 < r0, "smoother did not reduce residual");

    anyhow::ensure!(apps::fmm::functional(&m)?, "FMM RMA ring failed");
    s.push_str("FMM one-sided ring: data integrity PASS\n");

    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ids_run() {
        // smoke every performance-mode experiment (cheap configs inside)
        for id in ["table1", "fig6", "fig7", "fig16", "graph500", "hpcg",
                   "fig17", "fig18", "fig19", "fig20"] {
            let out = run(id).unwrap();
            assert!(out.contains("paper:"), "{id}: {out}");
        }
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99").is_err());
    }

    #[test]
    fn campaign_experiment_reports_every_scenario() {
        let out = run("campaign").unwrap();
        for name in ["gpcnet_isolated", "incast_8x16", "degraded_half_bw",
                     "coll_vs_incast", "hacc_step_closed"] {
            assert!(out.contains(name), "missing {name}: {out}");
        }
    }

    #[test]
    fn key_metrics_are_finite_and_keyed_uniquely() {
        let m = key_metrics();
        assert!(m.len() >= 15, "{}", m.len());
        let mut keys: Vec<&str> = m.iter().map(|(k, _)| *k).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), m.len(), "duplicate metric keys");
        for (k, v) in &m {
            assert!(v.is_finite() && *v > 0.0, "{k} = {v}");
        }
    }

    #[test]
    fn table2_has_nine_rows() {
        let out = run("table2").unwrap();
        assert!(out.contains("9234"));
        assert!(out.contains("5439"));
    }
}
