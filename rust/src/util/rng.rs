//! PCG-XSH-RR 64/32 pseudo-random generator: small, fast, deterministic —
//! used for adaptive-routing tie-breaks, workload generators and the
//! in-tree property tests. (The registry is offline; `rand` is replaced by
//! this ~60-line implementation.)

#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire rejection-free approximation is
    /// overkill here; modulo bias is negligible for our bounds).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    #[inline]
    pub fn gen_usize(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_respected() {
        let mut r = Pcg::new(7);
        for _ in 0..1000 {
            assert!(r.gen_range(13) < 13);
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Pcg::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.gen_usize(8)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Pcg::new(9);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(p, (0..100).collect::<Vec<_>>(), "identity is unlikely");
    }
}
