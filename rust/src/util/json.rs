//! Minimal JSON parser + serializer (the registry is offline; serde is
//! replaced by this module). Parses the artifact manifest written by
//! python/compile/aot.py and serializes campaign reports and golden test
//! fixtures. Supports the full JSON grammar we emit: objects, arrays,
//! strings (with escapes), numbers, bools, null. Serialization is
//! deterministic: object keys are `BTreeMap`-ordered and numbers use
//! Rust's shortest round-trip `f64` formatting, so equal values always
//! produce byte-identical text (the campaign determinism tests rely on
//! this).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact deterministic serialization (no whitespace).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty deterministic serialization (2-space indent).
    pub fn dump_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in, colon) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
                ": ",
            ),
            None => ("", String::new(), String::new(), ":"),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // JSON has no NaN/Inf; emit null (matches python's strict
                // encoders with allow_nan=False semantics)
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    e.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push_str(colon);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    // ---- construction helpers (keep call sites terse) ----

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i,
                self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // collect the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
  "hpl_update": {
    "args": [{"shape": [128, 64], "dtype": "float64"}],
    "file": "hpl_update.hlo.txt",
    "flops": 2097152.0,
    "outputs": [{"shape": [128, 128], "dtype": "float64"}]
  }
}"#;
        let j = Json::parse(text).unwrap();
        let e = j.get("hpl_update").unwrap();
        assert_eq!(e.get("file").unwrap().as_str(), Some("hpl_update.hlo.txt"));
        assert_eq!(e.get("flops").unwrap().as_f64(), Some(2_097_152.0));
        let shape = e.get("args").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(0).unwrap().as_usize(), Some(128));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        assert_eq!(j.idx(0).unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(j.idx(1).unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""§3.8 µs — ok""#).unwrap();
        assert_eq!(j.as_str(), Some("§3.8 µs — ok"));
    }

    #[test]
    fn dump_roundtrips() {
        let j = Json::obj(vec![
            ("name", Json::str("incast_64")),
            ("makespan", Json::num(0.0125)),
            ("flows", Json::arr(vec![Json::num(1.0), Json::num(2.5)])),
            ("ok", Json::Bool(true)),
            ("skip", Json::Null),
        ]);
        for text in [j.dump(), j.dump_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j, "text: {text}");
        }
    }

    #[test]
    fn dump_is_deterministic_and_sorted() {
        let a = Json::obj(vec![("b", Json::num(2.0)), ("a", Json::num(1.0))]);
        let b = Json::obj(vec![("a", Json::num(1.0)), ("b", Json::num(2.0))]);
        assert_eq!(a.dump(), b.dump());
        assert_eq!(a.dump(), r#"{"a":1,"b":2}"#);
    }

    #[test]
    fn dump_escapes_strings() {
        let j = Json::str("a\"b\\c\nd");
        let text = j.dump();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(Json::num(f64::NAN).dump(), "null");
        assert_eq!(Json::num(f64::INFINITY).dump(), "null");
    }
}
