//! Source-level determinism lint (`detlint`): the engine behind
//! `src/bin/detlint.rs` and `tests/detlint.rs`.
//!
//! The engine's headline guarantees — bit-identical campaign reports at
//! any thread count, exact streamed/staged equivalence — rest on a few
//! source-level contracts that nothing in the type system enforces:
//! iteration must never depend on a randomized hash order, no wall
//! clock may leak into simulated time, threads are only created by the
//! pooled worker protocol, and rate arithmetic stays in `f64`. This
//! module enforces them as a lint over `rust/src/`:
//!
//! | rule | contract protected |
//! |---|---|
//! | `std-hash-container` | no `std::collections::{HashMap,HashSet}` in `fabric/`/`campaign/` — iteration order is per-process random (`RandomState`), which breaks byte-identical reports; use `FxHashMap` (deterministic hasher) behind sorted/dense commit order, or `BTreeMap` |
//! | `wall-clock` | no `Instant`/`SystemTime` anywhere in `src/` — simulated time is the only clock, and a wall-clock read makes results machine-dependent |
//! | `thread-spawn` | threads are created only by `campaign/pool.rs` — the pooled worker protocol is what the determinism argument (serial merge in component-id order) is proven against |
//! | `hash-iter-float-reduce` | no float `sum`/`fold` over hash-map iterators — float addition is not associative, so a hash-ordered reduction varies across processes; reduce over a sorted/dense order (integer reductions are order-independent: allowlist them) |
//! | `f32-rate` | no `f32` in `fabric/`/`campaign/` — rate arithmetic is `f64` end-to-end; a single `f32` round-trip breaks the 1e-9 oracle-equivalence tolerance |
//!
//! The offline vendored registry rules out `syn`, so this is a
//! line-oriented scanner, not a parser. Three mechanics keep it honest:
//! string-literal and comment contents are stripped before matching (a
//! panic message or doc comment naming `thread::spawn` is not a
//! violation — and the stripping is also why this file can name its own
//! needles), identifier matching is token-bounded (`FxHashMap` does not
//! match `HashMap`), and everything from a `#[cfg(test)]` line to the
//! end of the file is skipped (test modules sit at the bottom of every
//! file in this repo; test-local std containers can't perturb report
//! bytes). Known limitation: a statement split across lines is only
//! matched line-by-line — the rules target tokens (imports, calls,
//! types) that sit on one line in idiomatic code.
//!
//! Intentional exceptions live in `ci/detlint_allow.txt`, one per line:
//! `rule|path-suffix|line-needle|reason`. An exception must name the
//! rule, the file, and a substring of the exact offending line — so an
//! allowlist entry can never silently cover new code.

use std::fs;
use std::path::Path;

/// One lint finding.
#[derive(Debug, Clone)]
pub struct LintDiag {
    pub rule: &'static str,
    /// Path relative to the scanned source root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending source line, trimmed.
    pub text: String,
    /// Which determinism contract the rule protects.
    pub note: &'static str,
}

impl LintDiag {
    pub fn render(&self) -> String {
        format!(
            "detlint[{}] {}:{}: {}\n    {}",
            self.rule, self.path, self.line, self.text, self.note
        )
    }
}

/// Parsed `ci/detlint_allow.txt`: explicit, reviewed exceptions.
#[derive(Debug, Clone, Default)]
pub struct Allowlist {
    /// (rule, path suffix, line needle) — the reason column is for
    /// reviewers and not matched against.
    entries: Vec<(String, String, String)>,
}

impl Allowlist {
    /// Parse the `rule|path-suffix|line-needle|reason` format. `#`
    /// comment lines and blank lines are skipped; a malformed entry
    /// (fewer than 3 fields) is ignored rather than silently permitting
    /// anything.
    pub fn parse(text: &str) -> Self {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(4, '|').map(str::trim);
            if let (Some(rule), Some(path), Some(needle)) =
                (parts.next(), parts.next(), parts.next())
            {
                if !rule.is_empty() && !path.is_empty() && !needle.is_empty()
                {
                    entries.push((
                        rule.to_string(),
                        path.to_string(),
                        needle.to_string(),
                    ));
                }
            }
        }
        Self { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Does some entry permit this (rule, file, line)? The raw line is
    /// matched (not the sanitized one), so the needle can quote the
    /// code exactly as written.
    pub fn permits(&self, rule: &str, path: &str, raw_line: &str) -> bool {
        self.entries.iter().any(|(r, p, n)| {
            r == rule && path.ends_with(p.as_str()) && raw_line.contains(n)
        })
    }
}

/// Strip string-literal contents, char literals and `//` comments from
/// one line, so needles only match real code tokens. Lifetimes (`'t`)
/// are preserved; `"..."` bodies become spaces; everything from the
/// first remaining `//` is dropped.
fn sanitize(line: &str) -> String {
    let b = line.as_bytes();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'"' => {
                // string literal: skip to the closing quote
                out.push(' ');
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
            }
            b'\'' => {
                // char literal ('x', '\n', '\'') vs lifetime ('t in
                // generics): a char literal closes with a quote within
                // a few bytes
                let close = if i + 1 < b.len() && b[i + 1] == b'\\' {
                    // escaped char: '\x' or '\u{..}' — find the quote
                    (i + 2..b.len().min(i + 12)).find(|&j| b[j] == b'\'')
                } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                    Some(i + 2)
                } else {
                    None
                };
                match close {
                    Some(j) => {
                        out.push(' ');
                        i = j + 1;
                    }
                    None => {
                        out.push('\'');
                        i += 1;
                    }
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => break,
            c => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    out
}

/// Does `hay` contain `tok` as a whole identifier (not as a substring
/// of a longer identifier — `FxHashMap` must not match `HashMap`)?
fn contains_token(hay: &str, tok: &str) -> bool {
    let ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    let h = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(tok) {
        let s = from + pos;
        let e = s + tok.len();
        let pre = s == 0 || !ident(h[s - 1]);
        let post = e == h.len() || !ident(h[e]);
        if pre && post {
            return true;
        }
        from = s + 1;
    }
    false
}

const NOTE_HASH: &str = "std hash containers iterate in per-process \
     random order (RandomState); campaign bytes must not depend on it — \
     use FxHashMap behind sorted/dense commit order, or BTreeMap";
const NOTE_CLOCK: &str = "simulated time is the only clock; a wall-clock \
     read makes results machine-dependent";
const NOTE_SPAWN: &str = "threads are created only by campaign/pool.rs — \
     the pooled worker protocol the determinism proof covers";
const NOTE_REDUCE: &str = "float reduction over a hash-map iterator is \
     order-dependent (float addition is not associative); reduce over a \
     sorted or dense order — integer reductions are order-independent \
     and belong in ci/detlint_allow.txt";
const NOTE_F32: &str = "rate arithmetic is f64 end-to-end; an f32 \
     round-trip breaks the 1e-9 oracle-equivalence tolerance";

/// Scan one file's source. `rel` is the path relative to the source
/// root (`fabric/des.rs`), used for rule scoping and diagnostics.
pub fn scan_source(
    rel: &str,
    source: &str,
    allow: &Allowlist,
    diags: &mut Vec<LintDiag>,
) {
    let det_scope =
        rel.starts_with("fabric/") || rel.starts_with("campaign/");
    let pool_exempt = rel == "campaign/pool.rs";
    let mut push = |diags: &mut Vec<LintDiag>,
                    rule: &'static str,
                    note: &'static str,
                    lineno: usize,
                    raw: &str| {
        if !allow.permits(rule, rel, raw) {
            diags.push(LintDiag {
                rule,
                path: rel.to_string(),
                line: lineno,
                text: raw.trim().to_string(),
                note,
            });
        }
    };
    for (idx, raw) in source.lines().enumerate() {
        let lineno = idx + 1;
        if raw.trim_start().starts_with("#[cfg(test)]") {
            // test modules sit at the bottom; test-local containers
            // cannot perturb report bytes
            break;
        }
        let line = sanitize(raw);
        if line.trim().is_empty() {
            continue;
        }

        // R1 std-hash-container: std::collections::{HashMap,HashSet}
        // anywhere in the deterministic-order scope
        if det_scope
            && line.contains("std::collections::")
            && (contains_token(&line, "HashMap")
                || contains_token(&line, "HashSet"))
        {
            push(diags, "std-hash-container", NOTE_HASH, lineno, raw);
        }

        // R2 wall-clock: Instant / SystemTime anywhere in src/
        if contains_token(&line, "Instant")
            || contains_token(&line, "SystemTime")
        {
            push(diags, "wall-clock", NOTE_CLOCK, lineno, raw);
        }

        // R3 thread-spawn: only campaign/pool.rs may create threads
        if !pool_exempt
            && (line.contains("thread::spawn")
                || line.contains("thread::Builder"))
        {
            push(diags, "thread-spawn", NOTE_SPAWN, lineno, raw);
        }

        // R4 hash-iter-float-reduce: sum/fold over a hash-map iterator
        let hash_iter = line.contains(".values()")
            || line.contains(".keys()")
            || (line.contains(".iter()")
                && (contains_token(&line, "FxHashMap")
                    || contains_token(&line, "HashMap")
                    || contains_token(&line, "FxHashSet")
                    || contains_token(&line, "HashSet")));
        if det_scope
            && hash_iter
            && !line.contains("BTree")
            && (contains_token(&line, "sum") || contains_token(&line, "fold"))
        {
            push(diags, "hash-iter-float-reduce", NOTE_REDUCE, lineno, raw);
        }

        // R5 f32-rate: no f32 in the rate-arithmetic scope
        if det_scope && contains_token(&line, "f32") {
            push(diags, "f32-rate", NOTE_F32, lineno, raw);
        }
    }
}

/// Result of a whole-tree scan.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub diags: Vec<LintDiag>,
    pub files: usize,
}

/// Recursively scan every `.rs` file under `src_root` (sorted walk, so
/// diagnostics come out in a stable order).
pub fn scan_tree(src_root: &Path, allow: &Allowlist) -> ScanResult {
    let mut out = ScanResult::default();
    let mut stack = vec![src_root.to_path_buf()];
    let mut files = Vec::new();
    while let Some(dir) = stack.pop() {
        let Ok(rd) = fs::read_dir(&dir) else { continue };
        let mut entries: Vec<_> =
            rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                files.push(p);
            }
        }
    }
    files.sort();
    for p in files {
        let Ok(source) = fs::read_to_string(&p) else { continue };
        let rel = p
            .strip_prefix(src_root)
            .unwrap_or(&p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        scan_source(&rel, &source, allow, &mut out.diags);
        out.files += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_str(rel: &str, src: &str) -> Vec<LintDiag> {
        let mut d = Vec::new();
        scan_source(rel, src, &Allowlist::default(), &mut d);
        d
    }

    #[test]
    fn comments_and_strings_do_not_fire() {
        let src = "// would otherwise pay a thread::spawn each\n\
                   fn f() { panic!(\"no std::collections::HashMap here\"); }\n";
        assert!(scan_str("fabric/x.rs", src).is_empty());
    }

    #[test]
    fn fx_alias_does_not_match_hash_token() {
        let src = "use rustc_hash::FxHashMap;\n\
                   fn f(m: &FxHashMap<u32, f64>) -> usize { m.len() }\n";
        assert!(scan_str("fabric/x.rs", src).is_empty());
    }

    #[test]
    fn test_module_tail_is_skipped() {
        let src = "fn f() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   use std::collections::HashSet;\n\
                   }\n";
        assert!(scan_str("fabric/x.rs", src).is_empty());
    }

    #[test]
    fn scope_excludes_non_fabric_dirs() {
        let src = "use std::collections::HashMap;\nlet x: f32 = 0.0;\n";
        assert!(scan_str("runtime/x.rs", src).is_empty());
        assert_eq!(scan_str("fabric/x.rs", src).len(), 2);
    }

    #[test]
    fn pool_is_exempt_from_thread_spawn() {
        let src = "std::thread::spawn(move || worker_loop(&sh, me));\n";
        assert!(scan_str("campaign/pool.rs", src).is_empty());
        assert_eq!(scan_str("campaign/other.rs", src).len(), 1);
    }

    #[test]
    fn allowlist_permits_exact_rule_path_and_needle() {
        let allow = Allowlist::parse(
            "# comment\n\
             \n\
             hash-iter-float-reduce|fabric/x.rs|total: u64|integer sum\n",
        );
        assert_eq!(allow.len(), 1);
        let src = "let total: u64 = m.values().sum();\n";
        let mut d = Vec::new();
        scan_source("fabric/x.rs", src, &allow, &mut d);
        assert!(d.is_empty(), "allowlisted line must be permitted");
        // same line, different file: still fires
        let mut d2 = Vec::new();
        scan_source("fabric/y.rs", src, &allow, &mut d2);
        assert_eq!(d2.len(), 1);
    }

    #[test]
    fn render_names_rule_file_and_line() {
        let d = scan_str("fabric/x.rs", "let t = x as f32;\n");
        assert_eq!(d.len(), 1);
        let r = d[0].render();
        assert!(r.contains("detlint[f32-rate] fabric/x.rs:1"), "{r}");
    }
}
