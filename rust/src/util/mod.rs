//! Self-contained utilities (this repo builds offline; no clap/serde/rand).

pub mod detlint;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Pcg;
