//! Self-contained utilities (this repo builds offline; no clap/serde/rand).

pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Pcg;
