//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (shapes, dtypes, output arity, FLOP estimates) — plus
//! [`RunInfo`], the provenance header stamped onto every JSON report this
//! crate writes (campaign runs, golden fixtures), so downstream consumers
//! can version-check what they are parsing.

use crate::util::Json;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Provenance header for machine-written JSON reports.
#[derive(Debug, Clone, PartialEq)]
pub struct RunInfo {
    /// Report schema tag, e.g. `aurorasim.campaign/v1`.
    pub schema: String,
    /// Generator identity (crate + version).
    pub generator: String,
}

impl RunInfo {
    pub fn new(schema: &str) -> Self {
        Self {
            schema: schema.to_string(),
            generator: format!("aurorasim {}", env!("CARGO_PKG_VERSION")),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(self.schema.clone())),
            ("generator", Json::str(self.generator.clone())),
        ])
    }

    /// Validate that a report's `info` header carries `schema`.
    pub fn check(root: &Json, schema: &str) -> Result<()> {
        let got = root
            .get("info")
            .and_then(|i| i.get("schema"))
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("report missing info.schema"))?;
        ensure!(
            got == schema,
            "schema mismatch: report is '{got}', expected '{schema}'"
        );
        Ok(())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub flops: f64,
    pub sha256: String,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let obj = root
            .as_obj()
            .ok_or_else(|| anyhow!("manifest root must be an object"))?;
        let mut entries = BTreeMap::new();
        for (name, e) in obj {
            let tensor = |j: &Json| -> Result<TensorSpec> {
                Ok(TensorSpec {
                    shape: j
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("{name}: missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    dtype: j
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string(),
                })
            };
            let args = e
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing args"))?
                .iter()
                .map(tensor)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .map(tensor)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: e
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing file"))?
                        .to_string(),
                    args,
                    outputs,
                    flops: e
                        .get("flops")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0),
                    sha256: e
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                },
            );
        }
        Ok(Self { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "hpl_update": {
        "args": [
          {"shape": [128, 64], "dtype": "float64"},
          {"shape": [64, 128], "dtype": "float64"},
          {"shape": [128, 128], "dtype": "float64"}
        ],
        "file": "hpl_update.hlo.txt",
        "flops": 2097152.0,
        "outputs": [{"shape": [128, 128], "dtype": "float64"}],
        "sha256": "abcd"
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let s = m.get("hpl_update").unwrap();
        assert_eq!(s.args.len(), 3);
        assert_eq!(s.args[0].shape, vec![128, 64]);
        assert_eq!(s.args[0].elems(), 8192);
        assert_eq!(s.outputs[0].dtype, "float64");
        assert_eq!(s.flops, 2_097_152.0);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"x": {"args": []}}"#).is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // exercised fully by integration tests; here just tolerate absence
        if let Ok(m) = Manifest::load("artifacts/manifest.json") {
            assert!(m.len() >= 10);
            assert!(m.get("mxp_gemm").is_some());
        }
    }

    #[test]
    fn runinfo_roundtrip_and_check() {
        let info = RunInfo::new("aurorasim.test/v1");
        let root = Json::obj(vec![("info", info.to_json())]);
        RunInfo::check(&root, "aurorasim.test/v1").unwrap();
        assert!(RunInfo::check(&root, "aurorasim.test/v2").is_err());
        assert!(RunInfo::check(&Json::Null, "aurorasim.test/v1").is_err());
    }
}
