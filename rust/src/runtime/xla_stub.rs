//! Offline stand-in for the `xla` crate (xla-rs / xla_extension 0.5.1).
//!
//! The PJRT bindings cannot be resolved in the offline build, so this
//! module mirrors exactly the API surface `runtime::Runtime` uses and
//! fails at [`PjRtClient::cpu`] with a clear message. Every functional
//! consumer already degrades gracefully when `Runtime::open` errors
//! (tests skip with a notice, `repro functional` reports the error), so
//! the performance tiers — which never touch PJRT — are unaffected.
//!
//! To run the real artifacts, add `xla = "0.1"` to rust/Cargo.toml and
//! replace `use xla_stub as xla;` with `use ::xla;` in `runtime/mod.rs`.

#![allow(dead_code)]

#[derive(Debug, Clone)]
pub struct Error(pub &'static str);

type XlaResult<T> = std::result::Result<T, Error>;

const MSG: &str =
    "built without PJRT bindings (offline xla stub) — see runtime/xla_stub.rs";

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<Self> {
        Err(Error(MSG))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> XlaResult<PjRtLoadedExecutable> {
        Err(Error(MSG))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error(MSG))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(Error(MSG))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        Err(Error(MSG))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        Err(Error(MSG))
    }

    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        Err(Error(MSG))
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(Error(MSG))
    }
}
