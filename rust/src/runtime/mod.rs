//! PJRT compute runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs exactly once (`make artifacts`); afterwards the Rust binary
//! is self-contained: `PjRtClient::cpu()` -> `HloModuleProto::from_text_file`
//! -> `compile` -> `execute`. HLO *text* is the interchange format because
//! the crate's xla_extension 0.5.1 rejects jax>=0.5 serialized protos
//! (64-bit instruction ids).
//!
//! [`roofline`] provides the at-scale timing adapter: functional runs
//! execute the artifacts for real; performance-mode runs convert the
//! manifest's FLOP counts into simulated time on the Aurora node model.

pub mod manifest;
pub mod roofline;
mod xla_stub;

pub use manifest::{ArtifactSpec, Manifest};
pub use roofline::{Engine, NodeRoofline};

// Offline build: the PJRT bindings are stubbed (see xla_stub.rs). Swap
// for `use ::xla;` plus an `xla = "0.1"` dependency to execute real
// artifacts.
use xla_stub as xla;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Calls served (for the §3.8.8-style counter report).
    pub calls: std::cell::Cell<u64>,
}

/// The runtime: one PJRT CPU client + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Open the artifact directory (default `artifacts/`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} — run \
                `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self { client, dir, manifest, cache: HashMap::new() })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for `name`.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(
                name.to_string(),
                Executable { spec, exe, calls: std::cell::Cell::new(0) },
            );
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on f64 inputs (shapes per the manifest).
    /// Outputs are flattened f64 vectors in declaration order.
    pub fn call_f64(&mut self, name: &str, args: &[&[f64]])
        -> Result<Vec<Vec<f64>>> {
        self.call_impl(name, args, true)
    }

    /// Execute artifact `name` on f32 inputs; returns f64 for uniformity.
    pub fn call_f32(&mut self, name: &str, args: &[&[f64]])
        -> Result<Vec<Vec<f64>>> {
        self.call_impl(name, args, false)
    }

    fn call_impl(&mut self, name: &str, args: &[&[f64]], f64_in: bool)
        -> Result<Vec<Vec<f64>>> {
        self.load(name)?;
        let exec = &self.cache[name];
        let spec = &exec.spec;
        if args.len() != spec.args.len() {
            anyhow::bail!(
                "{name}: {} args given, {} expected",
                args.len(),
                spec.args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (a, aspec) in args.iter().zip(&spec.args) {
            let expect: usize = aspec.shape.iter().product::<usize>().max(1);
            if a.len() != expect {
                anyhow::bail!(
                    "{name}: arg length {} != shape {:?}",
                    a.len(),
                    aspec.shape
                );
            }
            let dims: Vec<i64> =
                aspec.shape.iter().map(|&d| d as i64).collect();
            let lit = if aspec.dtype == "float64" && f64_in {
                xla::Literal::vec1(a)
            } else {
                let v32: Vec<f32> = a.iter().map(|&x| x as f32).collect();
                xla::Literal::vec1(&v32)
            };
            let lit = if dims.is_empty() {
                lit
            } else {
                lit.reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = exec
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        exec.calls.set(exec.calls.get() + 1);
        // aot.py lowers with return_tuple=True: output is always a tuple
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for (p, ospec) in parts.into_iter().zip(&spec.outputs) {
            let v = if ospec.dtype == "float64" {
                p.to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?
            } else {
                p.to_vec::<f32>()
                    .map_err(|e| anyhow!("{e:?}"))?
                    .into_iter()
                    .map(|x| x as f64)
                    .collect()
            };
            out.push(v);
        }
        Ok(out)
    }

    /// FLOPs per call of an artifact (from the manifest) — feeds the
    /// roofline timing adapter.
    pub fn flops(&self, name: &str) -> f64 {
        self.manifest.get(name).map(|s| s.flops).unwrap_or(0.0)
    }

    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.cache
            .iter()
            .map(|(k, v)| (k.clone(), v.calls.get()))
            .collect()
    }
}
