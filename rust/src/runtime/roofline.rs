//! Roofline timing adapter: converts per-rank work (FLOPs, bytes touched)
//! into simulated time on the Aurora node model (paper §2 + §5.2-5.3).
//!
//! Functional-mode runs execute the PJRT artifacts for real numerics but
//! the *simulated clock* always advances by roofline time, so small
//! functional runs and full-scale performance runs share one time base.

use crate::config::AuroraConfig;

/// Precision/engine class of a compute phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// FP64 vector pipes (HPL, HPCG, Nekbone).
    Fp64,
    /// Mixed-precision matrix engines (HPL-MxP factor phase).
    Mxp,
    /// Memory-bound (HPCG SpMV/SymGS, AMR-Wind smoothers): bytes dominate.
    MemoryBound,
    /// Integer/branchy (HACC tree-walk, Graph500): fraction of FP64 pipes.
    Irregular,
}

#[derive(Debug, Clone)]
pub struct NodeRoofline {
    pub fp64_peak: f64,
    pub mxp_peak: f64,
    pub hbm_bw: f64,
    pub gemm_eff: f64,
    pub mxp_gemm_eff: f64,
}

impl NodeRoofline {
    pub fn new(cfg: &AuroraConfig) -> Self {
        Self {
            fp64_peak: cfg.node_fp64_peak,
            mxp_peak: cfg.node_mxp_peak,
            hbm_bw: cfg.gpu_hbm_bw_node,
            gemm_eff: cfg.gemm_eff,
            mxp_gemm_eff: cfg.mxp_gemm_eff,
        }
    }

    /// Time for one node to perform `flops` with `bytes` of HBM traffic.
    pub fn node_time(&self, engine: Engine, flops: f64, bytes: f64) -> f64 {
        let compute = match engine {
            Engine::Fp64 => flops / (self.fp64_peak * self.gemm_eff),
            Engine::Mxp => flops / (self.mxp_peak * self.mxp_gemm_eff),
            // memory-bound kernels are limited by HBM alone
            Engine::MemoryBound => 0.0,
            // integer/tree phases run at a calibrated fraction of fp64
            Engine::Irregular => flops / (self.fp64_peak * 0.08),
        };
        let mem = bytes / self.hbm_bw;
        compute.max(mem)
    }

    /// Time until a rank's work completes when `ppn` ranks share the node
    /// evenly: the node executes the aggregate work, everyone finishes
    /// together.
    pub fn rank_time(&self, engine: Engine, flops: f64, bytes: f64,
                     ppn: usize) -> f64 {
        self.node_time(engine, flops * ppn as f64, bytes * ppn as f64)
    }

    /// Achieved node GEMM rate (flops/s) — what HPL's update phase sees.
    pub fn gemm_rate(&self) -> f64 {
        self.fp64_peak * self.gemm_eff
    }

    pub fn mxp_rate(&self) -> f64 {
        self.mxp_peak * self.mxp_gemm_eff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rl() -> NodeRoofline {
        NodeRoofline::new(&AuroraConfig::aurora())
    }

    #[test]
    fn gemm_rate_matches_hpl_calibration() {
        // 139 TF peak x 0.87 ~ 121 TF/node achieved DGEMM
        let r = rl();
        let tf = r.gemm_rate() / 1e12;
        assert!((tf - 120.9).abs() < 1.0, "{tf}");
    }

    #[test]
    fn memory_bound_ignores_flops() {
        let r = rl();
        let t1 = r.node_time(Engine::MemoryBound, 1e15, 1e9);
        let t2 = r.node_time(Engine::MemoryBound, 1e9, 1e9);
        assert_eq!(t1, t2);
    }

    #[test]
    fn mxp_much_faster_than_fp64() {
        let r = rl();
        let f = 1e15;
        assert!(
            r.node_time(Engine::Mxp, f, 0.0)
                < r.node_time(Engine::Fp64, f, 0.0) / 5.0
        );
    }

    #[test]
    fn compute_vs_memory_crossover() {
        let r = rl();
        // very low intensity -> memory bound; high intensity -> compute
        let low = r.node_time(Engine::Fp64, 1e9, 1e12);
        assert!((low - 1e12 / r.hbm_bw).abs() / low < 1e-9);
        let high = r.node_time(Engine::Fp64, 1e15, 1e3);
        assert!((high - 1e15 / r.gemm_rate()).abs() / high < 1e-9);
    }
}
