//! `detlint` — the source-level determinism lint, runnable standalone
//! (`cargo run --bin detlint`) and in CI as a blocking job. The same
//! engine is exercised by `tests/detlint.rs`, which also proves every
//! rule class fires on the deliberately-violating fixtures under
//! `tests/fixtures/detlint/`.
//!
//! Exit status: 0 when `rust/src/` is clean (modulo the reviewed
//! exceptions in `ci/detlint_allow.txt`), 1 when any rule fires.

use aurorasim::util::detlint::{scan_tree, Allowlist};
use std::path::Path;

fn main() {
    let manifest = env!("CARGO_MANIFEST_DIR");
    let src = Path::new(manifest).join("src");
    let allow_path = Path::new(manifest).join("..").join("ci").join(
        "detlint_allow.txt",
    );
    let allow = match std::fs::read_to_string(&allow_path) {
        Ok(text) => Allowlist::parse(&text),
        Err(_) => Allowlist::default(),
    };
    let res = scan_tree(&src, &allow);
    for d in &res.diags {
        eprintln!("{}", d.render());
    }
    if res.diags.is_empty() {
        println!(
            "detlint: clean — {} file(s) scanned, {} allowlist entr(y/ies)",
            res.files,
            allow.len()
        );
    } else {
        eprintln!(
            "detlint: {} violation(s) in {} file(s) scanned \
             (intentional exceptions go in ci/detlint_allow.txt with a \
             reason)",
            res.diags.len(),
            res.files
        );
        std::process::exit(1);
    }
}
