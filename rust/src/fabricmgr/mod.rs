//! The HPE Slingshot Fabric Manager control plane (paper §3.5, §4.1-4.3).
//!
//! Runs "outside" the fabric on a management node pair (Active-Standby):
//! computes routing tables from the live topology, runs periodic sweep
//! services (Deployment / Dragonfly Routing / Live Topology — §4.2.2),
//! tracks link health (flaps, degraded lanes — §3.8.7/§3.4), supports
//! orchestrated maintenance (§4.2.4: drain a link, diagnose, restore,
//! without disturbing the running fabric), and carries the QoS profile
//! (§4.2.3) that the data plane enforces.

use crate::config::AuroraConfig;
use crate::fabric::qos::QosProfile;
use crate::topology::{LinkId, Topology};
use std::collections::{HashMap, HashSet};

/// Sweep cadences (§4.2.2 defaults).
#[derive(Debug, Clone)]
pub struct SweepIntervals {
    pub deployment: f64,
    pub routing: f64,
    pub topology: f64,
}

impl Default for SweepIntervals {
    fn default() -> Self {
        Self { deployment: 10.0, routing: 5.0, topology: 10.0 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkHealth {
    Healthy,
    /// Operating with 2 or 3 of 4 lanes (§3.4 degraded operation).
    Degraded(u8),
    /// In orchestrated maintenance: drained, not routed over.
    Maintenance,
    /// Flapping (reset + 3-5 s retune — §3.8.7).
    Flapping,
}

/// One fabric-manager instance.
pub struct FabricManager {
    pub cfg: AuroraConfig,
    pub sweeps: SweepIntervals,
    pub qos: QosProfile,
    pub link_health: HashMap<LinkId, LinkHealth>,
    /// Flap history per link (timestamps).
    flaps: HashMap<LinkId, Vec<f64>>,
    /// Is this instance the active one of the Active-Standby pair?
    pub active: bool,
    /// Simulated management time.
    pub now: f64,
    /// Completed sweeps per service.
    pub sweep_counts: HashMap<&'static str, u64>,
}

impl FabricManager {
    pub fn new(cfg: &AuroraConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            sweeps: SweepIntervals::default(),
            qos: QosProfile::llbebdet(),
            link_health: HashMap::new(),
            flaps: HashMap::new(),
            active: true,
            now: 0.0,
            sweep_counts: HashMap::new(),
        }
    }

    /// Effective bandwidth multiplier for a link (feeds `DesOpts.degraded`).
    pub fn bw_multiplier(&self, link: &LinkId) -> f64 {
        match self.link_health.get(link) {
            None | Some(LinkHealth::Healthy) => 1.0,
            Some(LinkHealth::Degraded(lanes)) => *lanes as f64 / 4.0,
            Some(LinkHealth::Maintenance) | Some(LinkHealth::Flapping) => 0.0,
        }
    }

    /// Links currently unusable for routing.
    pub fn drained_links(&self) -> HashSet<LinkId> {
        self.link_health
            .iter()
            .filter(|(_, h)| {
                matches!(h, LinkHealth::Maintenance | LinkHealth::Flapping)
            })
            .map(|(l, _)| *l)
            .collect()
    }

    /// Record a link flap (CASSINI edge link flap — §3.8.7). Links that
    /// flap repeatedly inside `window` seconds are marked for maintenance.
    pub fn record_flap(&mut self, link: LinkId, window: f64,
                       threshold: usize) {
        let ts = self.flaps.entry(link).or_default();
        ts.push(self.now);
        ts.retain(|&t| self.now - t <= window);
        if ts.len() >= threshold {
            self.link_health.insert(link, LinkHealth::Maintenance);
        } else {
            self.link_health.insert(link, LinkHealth::Flapping);
        }
    }

    /// A flapping link that finished retune (3-5 s) returns to service.
    pub fn retune_complete(&mut self, link: LinkId) {
        if matches!(self.link_health.get(&link), Some(LinkHealth::Flapping)) {
            self.link_health.insert(link, LinkHealth::Healthy);
        }
    }

    /// Orchestrated maintenance (§4.2.4): drain a link for diagnosis.
    pub fn enter_maintenance(&mut self, link: LinkId) {
        self.link_health.insert(link, LinkHealth::Maintenance);
    }

    /// Restore a link after hardware action + revalidation.
    pub fn restore(&mut self, link: LinkId) {
        self.link_health.insert(link, LinkHealth::Healthy);
        self.flaps.remove(&link);
    }

    pub fn set_degraded(&mut self, link: LinkId, lanes: u8) {
        assert!((1..=4).contains(&lanes));
        self.link_health.insert(
            link,
            if lanes == 4 { LinkHealth::Healthy } else { LinkHealth::Degraded(lanes) },
        );
    }

    /// Advance management time, firing due sweeps. Returns the services
    /// that ran. Aggressive (too-low) intervals raise FM load — modeled as
    /// sweep cost; very high intervals delay event handling (§4.2.2).
    pub fn tick(&mut self, dt: f64) -> Vec<&'static str> {
        let before = self.now;
        self.now += dt;
        let mut fired = Vec::new();
        for (name, iv) in [
            ("deployment", self.sweeps.deployment),
            ("routing", self.sweeps.routing),
            ("topology", self.sweeps.topology),
        ] {
            let n_before = (before / iv) as u64;
            let n_after = (self.now / iv) as u64;
            if n_after > n_before {
                *self.sweep_counts.entry(name).or_insert(0) +=
                    n_after - n_before;
                fired.push(name);
            }
        }
        fired
    }

    /// Number of switches under management (the simulation framework of
    /// §4.1 validated the FM at 5,600 switches; Aurora runs 5,600).
    pub fn switch_count(&self) -> usize {
        self.cfg.total_groups() * self.cfg.switches_per_group
    }

    /// Routing-table generation: for every (src switch, dst group) pair
    /// the FM programs the minimal port plus non-minimal alternatives.
    /// Returns the table size — the scalability metric of §4.1.
    pub fn routing_table_entries(&self, topo: &Topology) -> usize {
        let _ = topo;
        let switches = self.switch_count();
        let groups = self.cfg.total_groups();
        // one interval-routing entry per destination group per switch,
        // plus per-parallel-link alternates
        switches * groups * self.cfg.global_links_compute
    }

    /// Standby takeover (Active-Standby cluster of §3.5).
    pub fn failover(&mut self) {
        self.active = !self.active;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fm() -> FabricManager {
        FabricManager::new(&AuroraConfig::aurora())
    }

    #[test]
    fn manages_5600_switches() {
        // paper §4.1: FM validated to scale to 5,600 switches
        assert_eq!(fm().switch_count(), 5600);
    }

    #[test]
    fn sweeps_fire_at_default_cadence() {
        let mut f = fm();
        let fired = f.tick(10.0);
        assert!(fired.contains(&"deployment"));
        assert!(fired.contains(&"routing"));
        assert_eq!(f.sweep_counts["routing"], 2); // 5 s cadence
    }

    #[test]
    fn flap_then_retune_recovers() {
        let mut f = fm();
        let l = LinkId::Global { src: 0, dst: 1, idx: 0 };
        f.record_flap(l, 60.0, 3);
        assert_eq!(f.bw_multiplier(&l), 0.0, "flapping link drained");
        f.retune_complete(l);
        assert_eq!(f.bw_multiplier(&l), 1.0);
    }

    #[test]
    fn repeated_flaps_escalate_to_maintenance() {
        let mut f = fm();
        let l = LinkId::Global { src: 2, dst: 9, idx: 1 };
        for _ in 0..3 {
            f.record_flap(l, 60.0, 3);
            f.tick(1.0);
        }
        assert_eq!(f.link_health[&l], LinkHealth::Maintenance);
        // retune does NOT clear maintenance — needs explicit restore
        f.retune_complete(l);
        assert_eq!(f.link_health[&l], LinkHealth::Maintenance);
        f.restore(l);
        assert_eq!(f.link_health[&l], LinkHealth::Healthy);
    }

    #[test]
    fn degraded_link_multiplier() {
        let mut f = fm();
        let l = LinkId::Local { group: 0, a: 1, b: 2 };
        f.set_degraded(l, 2);
        assert_eq!(f.bw_multiplier(&l), 0.5);
        f.set_degraded(l, 4);
        assert_eq!(f.bw_multiplier(&l), 1.0);
    }

    #[test]
    fn failover_switches_active() {
        let mut f = fm();
        assert!(f.active);
        f.failover();
        assert!(!f.active);
    }

    #[test]
    fn routing_tables_scale_with_machine() {
        let f = fm();
        let topo = Topology::new(&f.cfg.clone());
        let entries = f.routing_table_entries(&topo);
        assert!(entries > 1_000_000, "{entries}");
    }
}
