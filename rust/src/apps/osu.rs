//! OSU / ALCF MPI microbenchmarks (paper §3.8.3-§3.8.4 and §5.1):
//!
//! * [`p2p_latency_sweep`] — Fig 10: point-to-point latency vs message
//!   size, host buffers, 16-message window, NIC-SRAM step at 128 B.
//! * [`socket_bandwidth`] — Fig 11 (host) / Fig 13 (GPU): aggregate
//!   off-socket bandwidth vs ranks-per-socket, NICs round-robined.
//! * [`single_nic_gpu_bw`] — Fig 12: GPU-buffer bandwidth, processes
//!   sharing one NIC.
//! * [`mbw_mr`] — Fig 6/7: osu_mbw_mr at scale and across PPN.

use crate::config::AuroraConfig;
use crate::fabric::analytic;
use crate::machine::Machine;
use crate::mpi::World;

/// Fig 10: latency vs size for synchronous send-recv with a window of 16.
pub fn p2p_latency_sweep(machine: &Machine, sizes: &[u64]) -> Vec<(u64, f64)> {
    let mut w = World::new(&machine.topo, machine.place_job(0, 2, 1));
    sizes
        .iter()
        .map(|&s| (s, w.p2p_latency(0, 1, s, 16)))
        .collect()
}

/// Fig 11 / Fig 13: aggregate off-socket bandwidth for `ranks` MPI
/// processes on one socket, assigned round-robin to that socket's 4 NICs,
/// all streaming large messages to a remote node.
pub fn socket_bandwidth(machine: &Machine, ranks: usize, gpu: bool) -> f64 {
    let cfg = &machine.cfg;
    let nics_per_socket = cfg.nics_per_node / cfg.sockets_per_node;
    let mut w = World::new(&machine.topo, machine.place_job(0, 2, 16));
    if gpu {
        w = w.gpu_buffers();
    }
    let bytes: u64 = 64 << 20;
    // ranks 0..ranks use socket-0 NICs round robin; receivers on node 1
    let msgs: Vec<(usize, usize, u64)> = (0..ranks)
        .map(|r| {
            // placement: local ranks of node 0 bound to socket-0 NICs
            let sender_local = (r % nics_per_socket) * 2 + (r / nics_per_socket) % 2;
            let _ = sender_local;
            (r, 16 + r, bytes) // node-1 local rank r as receiver
        })
        .collect();
    // override NIC binding: all senders on socket 0 (cxi0..cxi3 round robin)
    for r in 0..ranks {
        let nic_idx = r % nics_per_socket;
        w.nics[r] = machine.topo.nic_of_node(0, nic_idx);
    }
    let t = w.exchange_now(&msgs); // duration consumed: price now
    ranks as f64 * bytes as f64 / t
}

/// Fig 12: bandwidth for `ranks` processes with GPU buffers all bound to
/// the *same* NIC, as a function of message size.
pub fn single_nic_gpu_bw(machine: &Machine, ranks: usize, msg_bytes: u64)
    -> f64 {
    let mut w =
        World::new(&machine.topo, machine.place_job(0, 2, 8)).gpu_buffers();
    for r in 0..ranks {
        w.nics[r] = machine.topo.nic_of_node(0, 0); // everyone on cxi0
    }
    let msgs: Vec<(usize, usize, u64)> =
        (0..ranks).map(|r| (r, 8 + r, msg_bytes)).collect();
    let t = w.exchange_now(&msgs); // duration consumed: price now
    ranks as f64 * msg_bytes as f64 / t
}

/// Fig 6/7: osu_mbw_mr aggregate bandwidth (pairs = nodes/2 x ppn).
pub fn mbw_mr(cfg: &AuroraConfig, nodes: usize, ppn: usize, msg: u64) -> f64 {
    analytic::mbw_mr_aggregate(cfg, nodes, ppn, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn fig10_shape() {
        let m = machine();
        let pts = p2p_latency_sweep(&m, &[8, 64, 128, 1024, 1 << 20]);
        let lat: Vec<f64> = pts.iter().map(|p| p.1).collect();
        // flat small region, visible jump at 128 B, then growth
        assert!((lat[0] - lat[1]).abs() < 0.2e-6);
        assert!(lat[2] > lat[1] + 0.3e-6, "SRAM->DRAM step: {lat:?}");
        assert!(lat[4] > lat[3] * 5.0);
        // absolute small-message latency in the paper's low-single-digit
        // microsecond band
        assert!(lat[0] > 1e-6 && lat[0] < 6e-6, "{}", lat[0]);
    }

    #[test]
    fn fig11_linear_then_nic_shared() {
        let m = machine();
        let bw: Vec<f64> =
            [1, 2, 4, 8].iter().map(|&r| socket_bandwidth(&m, r, false)).collect();
        // linear up to 4 ranks (one per NIC)
        assert!(bw[1] > bw[0] * 1.7, "{bw:?}");
        assert!(bw[2] > bw[1] * 1.7, "{bw:?}");
        // second rank per NIC still helps (NICs not saturated by one rank)
        assert!(bw[3] > bw[2] * 1.2, "{bw:?}");
        // 8 ranks approach the paper's ~90 GB/s/socket
        assert!(bw[3] > 75e9 && bw[3] < 95e9, "socket agg {}", bw[3]);
    }

    #[test]
    fn fig13_gpu_socket_bandwidth_lower() {
        let m = machine();
        let host = socket_bandwidth(&m, 8, false);
        let gpu = socket_bandwidth(&m, 8, true);
        // paper: ~70 GB/s GPU vs ~90 GB/s host per socket
        assert!(gpu < host * 0.9, "gpu {gpu} host {host}");
        assert!(gpu > 55e9 && gpu < 80e9, "gpu agg {gpu}");
    }

    #[test]
    fn fig12_single_nic_effective_bw() {
        let m = machine();
        // one process cannot saturate the NIC even at 1 MB
        let one = single_nic_gpu_bw(&m, 1, 1 << 20);
        assert!(one < m.cfg.nic_eff_bw_gpu * 0.9, "one-proc {one}");
        // adding processes reaches ~ the effective GPU-NIC ceiling at 256KB+
        let many = single_nic_gpu_bw(&m, 4, 256 << 10);
        assert!(
            many > m.cfg.nic_eff_bw_gpu * 0.7,
            "multi-proc {many} vs {}",
            m.cfg.nic_eff_bw_gpu
        );
        assert!(many <= m.cfg.nic_eff_bw_gpu * 1.05);
    }

    #[test]
    fn fig7_ppn_scaling() {
        let cfg = AuroraConfig::aurora();
        let big = 1 << 20;
        for nodes in [16usize, 64, 256] {
            let b1 = mbw_mr(&cfg, nodes, 1, big);
            let b8 = mbw_mr(&cfg, nodes, 8, big);
            assert!(b8 > b1 * 4.0, "{nodes} nodes: {b1} {b8}");
        }
    }
}
