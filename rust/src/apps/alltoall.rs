//! MPI all2all fabric-validation benchmark (paper §3.8.1, Fig 4).
//!
//! "MPI all2all is considered as a vital pre-flight test prior to running
//! large scale HPC and AI Benchmarks" — the paper shows a 9,658-node
//! (77,264-NIC, PPN 16) sweep reaching 228.92 TB/s aggregate.
//!
//! Full-scale points use the analytic tier; small scales can be
//! cross-checked against the round/DES tiers (`small_scale_check`), which
//! is itself one of the tier-consistency integration tests.

use crate::config::AuroraConfig;
use crate::fabric::analytic;
use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};

#[derive(Debug, Clone)]
pub struct Alltoall {
    pub nodes: usize,
    pub ppn: usize,
}

#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub msg_bytes: u64,
    /// Aggregate bandwidth over all ranks, bytes/s (the Fig 4 y-axis).
    pub aggregate_bw: f64,
}

impl Alltoall {
    /// The paper's configuration: 9,658 nodes, PPN 16.
    pub fn paper() -> Self {
        Self { nodes: 9658, ppn: 16 }
    }

    /// Sweep per-pair transfer sizes (Fig 4 x-axis).
    pub fn sweep(&self, cfg: &AuroraConfig, sizes: &[u64]) -> Vec<SweepPoint> {
        sizes
            .iter()
            .map(|&s| SweepPoint {
                msg_bytes: s,
                aggregate_bw: analytic::alltoall_aggregate_bw(
                    cfg, self.nodes, self.ppn, s,
                ),
            })
            .collect()
    }

    /// Default Fig 4 size grid: 64 B .. 4 MiB.
    pub fn default_sizes() -> Vec<u64> {
        (6..=22).map(|p| 1u64 << p).collect()
    }

    /// Peak aggregate bandwidth of the sweep.
    pub fn peak(&self, cfg: &AuroraConfig) -> f64 {
        self.sweep(cfg, &Self::default_sizes())
            .into_iter()
            .map(|p| p.aggregate_bw)
            .fold(0.0, f64::max)
    }
}

/// Small-scale all2all through the MPI/round tier, returning aggregate
/// bandwidth — used to cross-validate the analytic tier.
pub fn small_scale_check(machine: &Machine, nodes: usize, ppn: usize,
                         msg_bytes: u64) -> f64 {
    let mut w = World::new(&machine.topo, machine.place_job(0, nodes, ppn));
    let n = nodes * ppn;
    let comm = Comm::world(n);
    let t = coll::alltoall(&mut w, &comm, msg_bytes);
    // every rank sends to n-1 peers
    (n * (n - 1)) as f64 * msg_bytes as f64 / t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_peak() {
        let cfg = AuroraConfig::aurora();
        let peak = Alltoall::paper().peak(&cfg);
        let tb = peak / 1e12;
        assert!((tb - 228.92).abs() / 228.92 < 0.10, "peak {tb} TB/s");
    }

    #[test]
    fn sweep_is_monotone_nondecreasing() {
        let cfg = AuroraConfig::aurora();
        let pts = Alltoall::paper().sweep(&cfg, &Alltoall::default_sizes());
        for w in pts.windows(2) {
            assert!(w[1].aggregate_bw >= w[0].aggregate_bw * 0.999);
        }
    }

    #[test]
    fn small_scale_tiers_agree_within_factor_two() {
        // round tier vs analytic tier on an 8-node all2all
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let got = small_scale_check(&m, 8, 2, 64 << 10);
        let predicted =
            analytic::alltoall_aggregate_bw(&m.cfg, 8, 2, 64 << 10);
        let ratio = got / predicted;
        assert!(
            (0.3..3.0).contains(&ratio),
            "tier mismatch: round {got:.3e} vs analytic {predicted:.3e}"
        );
    }
}
