//! Graph500 BFS (paper §5.2.3): 69,373 GTEPS at scale 42 on 8,192 nodes.
//!
//! * [`functional`] — a real Kronecker graph + distributed-style BFS over
//!   1-D partitioned ranks with frontier exchanges through the simulated
//!   MPI world, validated by the Graph500 parent-tree checks.
//! * [`performance`] — GTEPS model: BFS is communication-bound at scale;
//!   frontier updates move ~[`BYTES_PER_EDGE`] bytes per input edge
//!   through the all2all fabric ceiling, plus per-level allreduce syncs.

use crate::config::AuroraConfig;
use crate::fabric::analytic;
use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};
use crate::util::Pcg;

/// Effective bytes crossing the fabric per input edge (bitmap-compressed
/// frontier updates; calibrated from the paper's 69,373 GTEPS).
pub const BYTES_PER_EDGE: f64 = 2.80;

/// Graph500 edge factor.
pub const EDGE_FACTOR: u64 = 16;

#[derive(Debug, Clone)]
pub struct GteepsRun {
    pub nodes: usize,
    pub scale: u32,
    pub bfs_time: f64,
    pub gteps: f64,
}

/// GTEPS performance model.
pub fn performance(cfg: &AuroraConfig, nodes: usize, scale: u32) -> GteepsRun {
    let edges = (1u128 << scale) as f64 * EDGE_FACTOR as f64;
    // frontier exchange: all input edges generate (compressed) remote
    // updates through the all2all ceiling of the job
    let a2a = analytic::alltoall_aggregate_bw(cfg, nodes, 8, 64 << 10);
    let t_comm = edges * BYTES_PER_EDGE / a2a;
    // local edge processing: memory bound
    let t_mem = edges * 8.0 / (nodes as f64 * cfg.gpu_hbm_bw_node);
    // ~16 BFS levels of barrier/allreduce at scale
    let t_sync = 16.0 * 40.0e-6;
    let bfs_time = t_comm + t_mem + t_sync;
    GteepsRun { nodes, scale, bfs_time, gteps: edges / bfs_time / 1e9 }
}

// ------------------------------------------------------------- functional

/// Kronecker-style edge generator (Graph500 R-MAT parameters).
pub fn kronecker_edges(scale: u32, seed: u64) -> Vec<(u32, u32)> {
    let n_edges = (1u64 << scale) * EDGE_FACTOR;
    let (a, b, c) = (0.57, 0.19, 0.19);
    let mut rng = Pcg::new(seed);
    let mut edges = Vec::with_capacity(n_edges as usize);
    for _ in 0..n_edges {
        let (mut u, mut v) = (0u32, 0u32);
        for _ in 0..scale {
            let r = rng.gen_f64();
            let (ubit, vbit) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | ubit;
            v = (v << 1) | vbit;
        }
        edges.push((u, v));
    }
    edges
}

#[derive(Debug, Clone)]
pub struct BfsResult {
    pub parent: Vec<i64>,
    pub visited: usize,
    pub levels: usize,
    pub teps: f64,
    pub sim_time: f64,
}

/// Distributed-style BFS: vertices partitioned round-robin over ranks;
/// each level exchanges cross-partition frontier updates through the
/// simulated fabric (all2allv) and synchronizes with an allreduce.
pub fn functional(machine: &Machine, scale: u32, ranks: usize, root: u32)
    -> BfsResult {
    functional_impl(machine, scale, ranks, root, false)
}

/// Closed-loop BFS: the same algorithm on `FabricTier::Des` with
/// superstep staging — each level's frontier exchange and its
/// frontier-done allreduce price as one dependency-released DAG, so
/// exchange congestion delays the level vote (and every later level)
/// instead of being summed independently.
pub fn functional_closed_loop(
    machine: &Machine,
    scale: u32,
    ranks: usize,
    root: u32,
) -> BfsResult {
    functional_impl(machine, scale, ranks, root, true)
}

fn functional_impl(
    machine: &Machine,
    scale: u32,
    ranks: usize,
    root: u32,
    closed_loop: bool,
) -> BfsResult {
    let n = 1u32 << scale;
    let edges = kronecker_edges(scale, 42);
    // adjacency (undirected)
    let mut adj = vec![Vec::new(); n as usize];
    for &(u, v) in &edges {
        if u != v {
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
    }
    let nodes = (ranks + 7) / 8;
    let mut w = World::new(
        &machine.topo,
        machine.place_job(0, nodes.max(1), ranks.min(8)),
    );
    if closed_loop {
        w = w.des_fabric();
        w.begin_superstep();
    }
    let comm = Comm::world(ranks);

    let owner = |v: u32| (v as usize) % ranks;
    let mut parent = vec![-1i64; n as usize];
    parent[root as usize] = root as i64;
    let mut frontier = vec![root];
    let mut levels = 0;
    let mut visited = 1usize;
    while !frontier.is_empty() {
        levels += 1;
        // expand locally; collect remote updates per destination rank
        let mut updates: Vec<Vec<(u32, u32)>> = vec![Vec::new(); ranks];
        for &u in &frontier {
            for &v in &adj[u as usize] {
                if parent[v as usize] < 0 {
                    updates[owner(v)].push((v, u));
                }
            }
        }
        // cost the exchange: per-rank pair message sizes
        let mut msgs = Vec::new();
        for (dst, ups) in updates.iter().enumerate() {
            if ups.is_empty() {
                continue;
            }
            // updates originate from the owners of the frontier vertices;
            // aggregate per (src,dst) rank pair
            let mut per_src = vec![0u64; ranks];
            for &(_, u) in ups {
                per_src[owner(u)] += 8;
            }
            for (src, bytes) in per_src.into_iter().enumerate() {
                if bytes > 0 && src != dst {
                    msgs.push((src, dst, bytes));
                }
            }
        }
        w.exchange(&msgs);
        // apply updates (deterministic order: lowest parent wins)
        let mut next = Vec::new();
        for ups in updates {
            for (v, u) in ups {
                if parent[v as usize] < 0 {
                    parent[v as usize] = u as i64;
                    next.push(v);
                    visited += 1;
                }
            }
        }
        coll::allreduce(&mut w, &comm, 8); // frontier-done vote
        frontier = next;
    }
    w.end_superstep(); // no-op unless closed-loop staging was active
    let traversed: usize =
        edges.iter().filter(|(u, _)| parent[*u as usize] >= 0).count();
    let sim_time = w.elapsed();
    BfsResult {
        parent,
        visited,
        levels,
        teps: traversed as f64 / sim_time,
        sim_time,
    }
}

/// Graph500 validation: parent edges exist, root is its own parent, and
/// every visited vertex reaches the root through decreasing levels.
pub fn validate_bfs(scale: u32, result: &BfsResult, root: u32) -> bool {
    let edges = kronecker_edges(scale, 42);
    let mut set = std::collections::HashSet::new();
    for &(u, v) in &edges {
        set.insert((u, v));
        set.insert((v, u));
    }
    if result.parent[root as usize] != root as i64 {
        return false;
    }
    for (v, &p) in result.parent.iter().enumerate() {
        if p < 0 || v == root as usize {
            continue;
        }
        if !set.contains(&(p as u32, v as u32)) {
            return false; // tree edge not in graph
        }
    }
    // depth consistency via walk-to-root with cycle bound
    for (v, &p) in result.parent.iter().enumerate() {
        if p < 0 {
            continue;
        }
        let mut cur = v as u32;
        let mut steps = 0;
        while cur != root {
            cur = result.parent[cur as usize] as u32;
            steps += 1;
            if steps > result.levels + 1 {
                return false; // cycle or over-deep
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_gteps() {
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 8192, 42);
        assert!(
            (run.gteps - 69_373.0).abs() / 69_373.0 < 0.10,
            "{} GTEPS",
            run.gteps
        );
    }

    #[test]
    fn gteps_grows_with_nodes() {
        let cfg = AuroraConfig::aurora();
        let g1 = performance(&cfg, 1024, 38).gteps;
        let g8 = performance(&cfg, 8192, 41).gteps;
        assert!(g8 > g1 * 3.0, "{g1} vs {g8}");
    }

    #[test]
    fn functional_bfs_validates() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let res = functional(&m, 10, 8, 1);
        assert!(res.visited > 512, "kronecker giant component");
        assert!(validate_bfs(10, &res, 1), "BFS tree must validate");
        assert!(res.levels >= 3 && res.levels < 30);
    }

    #[test]
    fn closed_loop_bfs_validates_and_prices_levels() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let res = functional_closed_loop(&m, 10, 8, 1);
        assert!(res.visited > 512, "kronecker giant component");
        assert!(validate_bfs(10, &res, 1), "closed-loop BFS tree validates");
        assert!(res.sim_time > 0.0, "supersteps must advance clocks");
        // identical traversal as the open-loop run (only timing differs)
        let open = functional(&m, 10, 8, 1);
        assert_eq!(res.parent, open.parent);
        assert_eq!(res.levels, open.levels);
    }

    #[test]
    fn bfs_visits_match_reachability() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let res = functional(&m, 8, 4, 0);
        // every vertex with a parent was visited exactly once
        let with_parent =
            res.parent.iter().filter(|&&p| p >= 0).count();
        assert_eq!(with_parent, res.visited);
    }
}
