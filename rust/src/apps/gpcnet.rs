//! GPCNet network load test (paper §3.8.2, Fig 5).
//!
//! GPCNet measures natural-ring and random-ring patterns plus a multiple
//! allreduce, first isolated and then concurrently with congestor traffic
//! (incast + broad background flows), reporting the **Congestion Impact
//! Factor** (CIF = congested / isolated) at the 99th percentile and mean.
//! Aurora's Slingshot congestion management kept CIF small (1.0-10.6x in
//! Fig 5) at 9,658 nodes — the largest GPCNet run ever.
//!
//! The DES tier runs the experiment at reduced scale with congestion
//! management on (Slingshot) and off (the classic-fabric baseline GPCNet
//! was designed to embarrass).

use crate::fabric::des::{DesOpts, DesSim, TimedFlow};
use crate::fabric::{Flow, Router, RoutedFlow};
use crate::machine::Machine;
use crate::metrics::{mean, percentile};
use crate::util::Pcg;

#[derive(Debug, Clone)]
pub struct GpcnetReport {
    pub rr_lat_isolated: (f64, f64),  // (avg, p99)
    pub rr_lat_congested: (f64, f64),
    pub rr_bw_isolated: (f64, f64),   // bytes/s/rank (avg, p99-low)
    pub rr_bw_congested: (f64, f64),
    pub cif_lat: (f64, f64),          // (avg, p99) impact factors
    pub cif_bw: (f64, f64),
}

pub struct Gpcnet {
    /// Victim (network-test) ranks — 60% of job in the paper's run.
    pub victims: usize,
    /// Congestor ranks — 40%.
    pub congestors: usize,
    pub rr_bytes: u64,
    pub lat_bytes: u64,
}

impl Default for Gpcnet {
    fn default() -> Self {
        Self { victims: 96, congestors: 64, rr_bytes: 128 << 10, lat_bytes: 8 }
    }
}

impl Gpcnet {
    fn random_ring_flows(&self, machine: &Machine, seed: u64, bytes: u64)
        -> Vec<Flow> {
        let nodes = machine.cfg.nodes();
        let mut rng = Pcg::new(seed);
        let perm = rng.permutation(self.victims);
        (0..self.victims)
            .map(|i| {
                let peer = perm[i];
                let src_node = i % nodes;
                let dst_node = peer % nodes;
                Flow::new(
                    machine.topo.nic_of_node(src_node, i % 8) ,
                    machine.topo.nic_of_node(dst_node, peer % 8),
                    bytes,
                )
            })
            .filter(|f| f.src_nic != f.dst_nic)
            .collect()
    }

    /// Congestors: a handful of hard incasts plus background all-to-all.
    fn congestor_flows(&self, machine: &Machine, seed: u64) -> Vec<Flow> {
        let nodes = machine.cfg.nodes();
        let mut rng = Pcg::new(seed ^ 0xc0f);
        let mut flows = Vec::new();
        let incast_roots = (self.congestors / 16).max(1);
        for r in 0..incast_roots {
            let root = rng.gen_usize(nodes);
            let root_nic = machine.topo.nic_of_node(root, 0);
            for _ in 0..12 {
                let src = rng.gen_usize(nodes);
                let src_nic = machine.topo.nic_of_node(src, rng.gen_usize(8));
                if src_nic != root_nic {
                    flows.push(Flow::new(src_nic, root_nic, 8 << 20));
                }
            }
            let _ = r;
        }
        for _ in 0..self.congestors {
            let a = rng.gen_usize(nodes);
            let b = rng.gen_usize(nodes);
            let fa = machine.topo.nic_of_node(a, rng.gen_usize(8));
            let fb = machine.topo.nic_of_node(b, rng.gen_usize(8));
            if fa != fb {
                flows.push(Flow::new(fa, fb, 4 << 20));
            }
        }
        flows
    }

    fn run_case(&self, machine: &Machine, victims: &[Flow],
                congestors: &[Flow], congestion_mgmt: bool)
        -> (Vec<f64>, Vec<f64>) {
        let mut router = Router::new(&machine.topo);
        let routed: Vec<RoutedFlow> = victims
            .iter()
            .chain(congestors.iter())
            .map(|f| RoutedFlow { flow: f.clone(), path: router.route(f) })
            .collect();
        let timed: Vec<TimedFlow> = routed
            .into_iter()
            .map(|rf| TimedFlow { rf, start: 0.0 })
            .collect();
        let sim = DesSim::new(
            &machine.topo,
            DesOpts { congestion_mgmt, ..DesOpts::default() },
        );
        let res = sim.run(&timed);
        let vic_times: Vec<f64> =
            res.finish[..victims.len()].to_vec();
        let vic_bw: Vec<f64> = victims
            .iter()
            .zip(&vic_times)
            .map(|(f, t)| f.bytes as f64 / t)
            .collect();
        (vic_times, vic_bw)
    }

    /// Full GPCNet experiment at reduced scale. `slingshot = true` runs
    /// with the paper's congestion management.
    pub fn run(&self, machine: &Machine, slingshot: bool) -> GpcnetReport {
        // --- isolated: victims only ---
        let lat_flows = self.random_ring_flows(machine, 1, self.lat_bytes);
        let bw_flows = self.random_ring_flows(machine, 2, self.rr_bytes);
        let (iso_lat, _) = self.run_case(machine, &lat_flows, &[], slingshot);
        let (_, iso_bw) = self.run_case(machine, &bw_flows, &[], slingshot);
        // --- congested ---
        let cong = self.congestor_flows(machine, 3);
        let (con_lat, _) =
            self.run_case(machine, &lat_flows, &cong, slingshot);
        let (_, con_bw) = self.run_case(machine, &bw_flows, &cong, slingshot);

        let p99 = |v: &[f64]| percentile(v, 99.0);
        let p01 = |v: &[f64]| percentile(v, 1.0); // 99% worst bw = low tail
        GpcnetReport {
            rr_lat_isolated: (mean(&iso_lat), p99(&iso_lat)),
            rr_lat_congested: (mean(&con_lat), p99(&con_lat)),
            rr_bw_isolated: (mean(&iso_bw), p01(&iso_bw)),
            rr_bw_congested: (mean(&con_bw), p01(&con_bw)),
            cif_lat: (
                mean(&con_lat) / mean(&iso_lat),
                p99(&con_lat) / p99(&iso_lat),
            ),
            cif_bw: (
                mean(&iso_bw) / mean(&con_bw),
                p01(&iso_bw) / p01(&con_bw).max(1e-9),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    fn machine() -> Machine {
        Machine::new(&AuroraConfig::small(8, 4))
    }

    #[test]
    fn congestion_inflates_latency_moderately_with_mgmt() {
        let m = machine();
        let rep = Gpcnet::default().run(&m, true);
        // Fig 5: avg CIF between 1x and ~11x with congestion management
        assert!(rep.cif_lat.0 >= 1.0, "CIF {:?}", rep.cif_lat);
        assert!(rep.cif_lat.0 < 30.0, "CIF too large: {:?}", rep.cif_lat);
    }

    #[test]
    fn slingshot_beats_no_congestion_mgmt() {
        let m = machine();
        let with = Gpcnet::default().run(&m, true);
        let without = Gpcnet::default().run(&m, false);
        // victims must fare no worse with congestion management
        assert!(
            with.cif_bw.0 <= without.cif_bw.0 * 1.05,
            "with {:?} without {:?}",
            with.cif_bw,
            without.cif_bw
        );
    }

    #[test]
    fn isolated_latency_in_microsecond_band() {
        let m = machine();
        let rep = Gpcnet::default().run(&m, true);
        // Fig 5 isolated: avg 3.1 us, 99% 5.2 us (8 B random ring)
        assert!(
            rep.rr_lat_isolated.0 > 1e-6 && rep.rr_lat_isolated.0 < 20e-6,
            "avg {}",
            rep.rr_lat_isolated.0
        );
        assert!(rep.rr_lat_isolated.1 >= rep.rr_lat_isolated.0);
    }
}
