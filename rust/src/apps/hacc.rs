//! HACC cosmology (paper §5.3.1, Fig 17, Table 3): weak scaling at
//! 128 / 1,024 / 8,192 nodes (PPN 96), efficiency 100% -> 99% -> 97%.
//!
//! Per step, three phases (§5.3.1):
//! 1. short-range force kernel — compute-intensive, stride-one (the
//!    `hacc_short_range` artifact);
//! 2. tree walk — irregular, integer-heavy (roofline `Irregular` engine);
//! 3. long-range 3D FFT — dominated by point-to-point transpose
//!    communication (pencil all2all over the fabric).

use crate::config::AuroraConfig;
use crate::fabric::analytic;
use crate::fabric::workload::{self, DagBuilder, DagWorkload};
use crate::fabric::Router;
use crate::machine::Machine;
use crate::runtime::{Engine, NodeRoofline, Runtime};
use crate::topology::Topology;
use anyhow::Result;

pub use super::ScalingPoint;

/// Table 3 configurations: (nodes, grid ng, MPI geometry).
pub const TABLE3: [(usize, u64, (usize, usize, usize)); 3] = [
    (128, 4608, (32, 24, 16)),
    (1024, 9216, (64, 48, 32)),
    (8192, 18432, (128, 96, 64)),
];

pub const PPN: usize = 96;

/// One weak-scaling step time at `nodes` with grid `ng` (Fig 17 bars).
pub fn step_time(cfg: &AuroraConfig, nodes: usize, ng: u64) -> f64 {
    let rl = NodeRoofline::new(cfg);
    let cells_per_node = (ng as f64).powi(3) / nodes as f64;
    let particles_per_node = cells_per_node; // ~1 particle/cell

    // 1. short-range: ~450 flops/particle-pair-tile step
    let f_short = particles_per_node * 450.0;
    let t_short = rl.node_time(Engine::Fp64, f_short * 6.0, 0.0);
    // 2. tree walk: irregular, ~200 int-ops/particle
    let t_tree =
        rl.node_time(Engine::Irregular, particles_per_node * 200.0,
                     particles_per_node * 48.0);
    // 3. FFT: 2 transposes x grid bytes through the all2all ceiling +
    // local FFT passes (memory-bound)
    let grid_bytes_node = cells_per_node * 8.0;
    let a2a_bw =
        analytic::alltoall_aggregate_bw(cfg, nodes, PPN.min(16), 256 << 10)
            / nodes as f64;
    let t_transpose = 2.0 * 2.0 * grid_bytes_node / a2a_bw;
    let t_fft_local = rl.node_time(
        Engine::MemoryBound,
        0.0,
        2.0 * 5.0 * grid_bytes_node * (ng as f64).log2() / 10.0,
    );
    // per-level sync latencies grow logarithmically with ranks
    let ranks = (nodes * PPN) as f64;
    let t_sync = 14.0 * 12.0e-6 * ranks.log2();
    let base = t_short + t_tree + t_transpose + t_fft_local + t_sync;
    // tree-walk load imbalance + RCB partition skew grow slowly with
    // scale (the 1%/3% losses of Fig 17)
    let imbalance = 0.005 * (nodes as f64 / 128.0).log2().max(0.0);
    base * (1.0 + imbalance)
}

/// Closed-loop HACC step trace (§5.3.1) as a dependency workload: a
/// short-range compute interval per rank, the long-range FFT transpose
/// (pencil all2all — P-1 pairwise rounds of grid_bytes/P), then the
/// tree-walk halo exchange (±1/±2/±3 neighbour faces). Each phase is
/// dependency-released by the previous one, so fabric congestion during
/// the transpose delays the halo — the coupling the analytic
/// [`step_time`] model cannot express. Reusable by the campaign engine
/// (`campaign::Workload::AppPhase`) and the equivalence sweeps.
pub fn step_dag(
    topo: &Topology,
    router: &mut Router,
    ranks: usize,
    grid_bytes: u64,
) -> DagWorkload {
    let nics = workload::spread_nics(topo, ranks);
    let mut b = DagBuilder::new();
    // per-rank short-range kernel: first-round transfers wait for it
    for &nic in &nics {
        b.compute(nic, 200e-6);
    }
    let mut rounds = Vec::new();
    // FFT transpose: pairwise all2all of grid_bytes / ranks per pair
    let chunk = (grid_bytes / ranks.max(1) as u64).max(1);
    rounds.extend(workload::pairwise_rounds(&nics, chunk));
    // halo exchange: 6 faces in the 1-D embedding, 1/8 of the grid slab
    rounds.push(workload::neighbor_round(
        &nics,
        &[-3, -2, -1, 1, 2, 3],
        (grid_bytes / 8).max(1),
    ));
    workload::push_rounds(&mut b, router, &rounds, 0.0);
    b.finish()
}

/// Drive one HACC step through the MPI [`World`] as dependency-released
/// supersteps: the short-range kernel per rank, then the FFT pairwise
/// transpose rounds, then the tree-walk halo — each `World::exchange`
/// round released by the previous one (on `FabricTier::Des` via
/// [`World::begin_superstep`]), so transpose congestion delays the halo
/// exactly like [`step_dag`] expresses at the fabric layer, but composed
/// from ordinary exchange calls any app can make. Works on both tiers
/// (the analytic tier prices rounds independently). Returns the step's
/// elapsed time.
pub fn step_world(
    w: &mut crate::mpi::World,
    ranks: usize,
    grid_bytes: u64,
) -> f64 {
    assert!(w.size() >= ranks, "world too small for {ranks} ranks");
    let t0 = w.elapsed();
    w.begin_superstep();
    for r in 0..ranks {
        w.superstep_compute(r, 200e-6); // short-range kernel
    }
    let chunk = (grid_bytes / ranks.max(1) as u64).max(1);
    for shift in 1..ranks {
        w.exchange(&super::rank_pairwise_round(ranks, shift, chunk));
    }
    let face = (grid_bytes / 8).max(1);
    w.exchange(&super::rank_halo_round(
        ranks,
        &[-3, -2, -1, 1, 2, 3],
        face,
    ));
    w.end_superstep();
    w.elapsed() - t0
}

/// Fig 17: weak-scaling times + efficiencies for the Table 3 points.
pub fn fig17(cfg: &AuroraConfig) -> Vec<ScalingPoint> {
    let pts: Vec<(usize, f64)> = TABLE3
        .iter()
        .map(|&(nodes, ng, _)| (nodes, step_time(cfg, nodes, ng)))
        .collect();
    super::weak_efficiency_from_times(&pts)
}

/// Functional demo: the short-range artifact produces momentum-conserving
/// forces and the FFT-Poisson artifact solves on a 32^3 grid; returns
/// (max |sum F|, poisson check residual).
pub fn functional(rt: &mut Runtime, _machine: &Machine) -> Result<(f64, f64)> {
    // forces on a 256-particle tile
    let mut rng = crate::util::Pcg::new(5);
    let pos: Vec<f64> = (0..256 * 3).map(|_| rng.gen_f64() * 2.0).collect();
    let f = rt.call_f32("hacc_short_range", &[&pos])?.remove(0);
    let mut sum = [0.0f64; 3];
    let mut maxf: f64 = 0.0;
    for i in 0..256 {
        for d in 0..3 {
            sum[d] += f[i * 3 + d];
            maxf = maxf.max(f[i * 3 + d].abs());
        }
    }
    let net = sum.iter().map(|s| s.abs()).fold(0.0, f64::max) / maxf.max(1e-12);

    // Poisson: phi = FFT^-1(G * FFT(rho)); applying -k^2 back yields rho
    let n = 32;
    let rho: Vec<f64> = (0..n * n * n)
        .map(|i| ((i % 17) as f64 - 8.0) / 8.0)
        .collect();
    let mean = rho.iter().sum::<f64>() / rho.len() as f64;
    let rho: Vec<f64> = rho.iter().map(|v| v - mean).collect();
    let phi = rt.call_f32("hacc_fft_poisson", &[&rho])?.remove(0);
    // spot-check: potential is smooth & zero-mean
    let pmean = phi.iter().sum::<f64>() / phi.len() as f64;
    Ok((net, pmean.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weak_scaling_matches_fig17() {
        let cfg = AuroraConfig::aurora();
        let pts = fig17(&cfg);
        assert_eq!(pts[0].efficiency, 1.0);
        // paper: ~99% at 1,024, ~97% at 8,192
        assert!(
            (0.96..=1.0).contains(&pts[1].efficiency),
            "1024-node eff {}",
            pts[1].efficiency
        );
        assert!(
            (0.93..=0.995).contains(&pts[2].efficiency),
            "8192-node eff {}",
            pts[2].efficiency
        );
        assert!(pts[2].efficiency < pts[1].efficiency);
    }

    #[test]
    fn grid_doubles_with_8x_nodes() {
        // Table 3 invariant: 8x nodes => 2x grid per dimension
        for w in TABLE3.windows(2) {
            assert_eq!(w[1].0, w[0].0 * 8);
            assert_eq!(w[1].1, w[0].1 * 2);
        }
    }

    #[test]
    fn step_dag_is_closed_loop_and_runs() {
        use crate::fabric::des::{DesOpts, DesSim};
        let topo = Topology::new(&AuroraConfig::small(4, 4));
        let mut router = Router::new(&topo);
        let dag = step_dag(&topo, &mut router, 12, 8 << 20);
        // 12 compute roots + pairwise (11 rounds x 12) + halo (12 x 6)
        assert_eq!(dag.len(), 12 + 11 * 12 + 12 * 6);
        let res = DesSim::new(&topo, DesOpts::default()).run_dag(&dag);
        assert!(res.makespan > 200e-6, "compute phase must gate comm");
        assert!(res.node_finish.iter().all(|t| t.is_finite()));
    }

    #[test]
    fn step_world_runs_closed_loop_and_chains_phases() {
        use crate::machine::Machine;
        use crate::mpi::World;
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut wd = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let td = step_world(&mut wd, 12, 8 << 20);
        assert!(td > 200e-6, "compute phase must gate comm: {td}");
        // the exchange-loop shape re-touches every rank each round, so
        // the flush prices on the windowed streaming executor
        let fs = wd.last_flush.expect("superstep flushed");
        assert!(fs.streamed, "app exchange loop must stream its flush");
        assert_eq!(fs.late_releases, 0);
        assert!(fs.peak_live_nodes < fs.total_nodes);
        // deterministic across identical worlds
        let mut wd2 = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let td2 = step_world(&mut wd2, 12, 8 << 20);
        assert!((td - td2).abs() < 1e-12, "{td} vs {td2}");
        // the analytic tier prices the same structure open-loop
        let mut wa = World::new(&m.topo, m.place_job(0, 12, 1));
        let ta = step_world(&mut wa, 12, 8 << 20);
        assert!(ta > 0.0);
    }

    #[test]
    fn fft_transpose_is_the_dominant_comm() {
        let cfg = AuroraConfig::aurora();
        // step time grows only mildly from 128 to 8192 nodes
        let t0 = step_time(&cfg, 128, 4608);
        let t2 = step_time(&cfg, 8192, 18432);
        assert!(t2 < t0 * 1.1, "weak scaling: {t0} -> {t2}");
    }
}
