//! AMR-Wind (paper §5.3.3, Fig 19): block-structured incompressible flow
//! solver (AMReX/SYCL) running atmospheric-boundary-layer LES; weak
//! scaling to 8,192 nodes with the FOM = billions of cells solved per
//! second per step.
//!
//! Paper setup: 256^3 cells per rank, PPN 12, domain grown in x/y with
//! node count (z fixed). Per step: advection/diffusion stencils
//! (memory-bound), an MLMG Poisson solve (V-cycles: smoothing per level,
//! coarse-grid allreduces), and face halo exchanges.

use crate::config::AuroraConfig;
use crate::fabric::workload::{self, DagBuilder, DagWorkload};
use crate::fabric::Router;
use crate::machine::Machine;
use crate::runtime::{Engine, NodeRoofline, Runtime};
use crate::topology::Topology;
use anyhow::Result;

pub use super::ScalingPoint;

pub const PPN: usize = 12;
pub const CELLS_PER_RANK: u64 = 256 * 256 * 256;

/// One time-step wall time at `nodes`.
pub fn step_time(cfg: &AuroraConfig, nodes: usize) -> f64 {
    let rl = NodeRoofline::new(cfg);
    let cells_node = (CELLS_PER_RANK * PPN as u64) as f64;
    // ~160 stencil sweeps-equivalent per step (advection + diffusion +
    // nodal projection + MLMG smoothing over V-cycle levels and
    // iterations), 8 B/cell each way
    let t_stencils =
        rl.node_time(Engine::MemoryBound, 0.0, cells_node * 8.0 * 2.0 * 160.0);
    // halo: 6 faces x 256^2 x 8 B per rank per sweep set
    let face_bytes = 12.0 * 6.0 * 256.0 * 256.0 * 8.0 * 8.0;
    let t_halo = face_bytes / (cfg.nic_eff_bw_host * cfg.nics_per_node as f64)
        + 12.0 * cfg.mpi_overhead;
    // MLMG coarse levels: log2(cells) levels, each with an allreduce-like
    // sync whose latency grows with log(ranks) — the weak-scaling tax
    let ranks = (nodes * PPN) as f64;
    let vcycle_levels = 8.0;
    let bottom_iters = 4.0;
    let t_mg_sync =
        vcycle_levels * bottom_iters * 10.0e-6 * ranks.log2().max(1.0);
    t_stencils + t_halo + t_mg_sync
}

/// Closed-loop AMR-Wind step trace (§5.3.3) as a dependency workload:
/// per V-cycle, a smoothing compute interval, face-halo exchanges (±1
/// neighbours, `halo_bytes` per face), and the bottom-solve residual
/// allreduce (recursive-doubling rounds of 8-byte tokens — the
/// latency-bound MLMG sync tax). Rounds are dependency-released, so
/// congestion in a halo phase pushes the residual reduction — and every
/// later V-cycle — out in time.
pub fn step_dag(
    topo: &Topology,
    router: &mut Router,
    ranks: usize,
    halo_bytes: u64,
) -> DagWorkload {
    let nics = workload::spread_nics(topo, ranks);
    let mut b = DagBuilder::new();
    for _vcycle in 0..2 {
        for &nic in &nics {
            b.compute(nic, 50e-6); // level smoothing
        }
        let mut rounds =
            vec![workload::neighbor_round(&nics, &[-1, 1], halo_bytes.max(1))];
        // bottom-solve residual allreduce: latency-bound 8 B tokens
        rounds.extend(workload::doubling_rounds(&nics, 8));
        workload::push_rounds(&mut b, router, &rounds, 0.0);
    }
    b.finish()
}

/// Drive one AMR-Wind step through the MPI [`World`]: per V-cycle a
/// smoothing compute interval, the face-halo exchange as a superstep
/// round, and the bottom-solve residual allreduce — which, on
/// `FabricTier::Des` with staging active, flushes the halo and the
/// allreduce's doubling rounds as **one** dependency-released DAG, so a
/// congested halo pushes the residual reduction (and the next V-cycle)
/// out in time. Returns the step's elapsed span.
pub fn step_world(
    w: &mut crate::mpi::World,
    ranks: usize,
    halo_bytes: u64,
) -> f64 {
    assert!(w.size() >= ranks, "world too small for {ranks} ranks");
    let t0 = w.elapsed();
    let comm = crate::mpi::Comm::world(ranks);
    w.begin_superstep();
    for _vcycle in 0..2 {
        for r in 0..ranks {
            w.superstep_compute(r, 50e-6); // level smoothing
        }
        w.exchange(&super::rank_halo_round(
            ranks,
            &[-1, 1],
            halo_bytes.max(1),
        ));
        // bottom-solve residual: a collective flush point — the halo
        // and the 8-byte allreduce price as one closed-loop DAG
        crate::mpi::coll::allreduce(w, &comm, 8);
    }
    w.end_superstep();
    w.elapsed() - t0
}

/// Fig 19: FOM (billion cells / second) + weak-scaling efficiency.
pub fn fig19(cfg: &AuroraConfig, node_counts: &[usize]) -> Vec<ScalingPoint> {
    let pts: Vec<(usize, f64)> = node_counts
        .iter()
        .map(|&nodes| {
            let cells = (CELLS_PER_RANK * (nodes * PPN) as u64) as f64;
            (nodes, cells / step_time(cfg, nodes) / 1e9)
        })
        .collect();
    super::weak_efficiency_from_rates(&pts)
}

/// Functional demo: the MLMG smoother level (`hpcg_symgs` artifact — the
/// same damped-Jacobi level smoother) reduces the residual on a 32^3 box.
pub fn functional(rt: &mut Runtime, _machine: &Machine) -> Result<(f64, f64)> {
    let n = 32usize;
    let g = n + 2;
    let mut rng = crate::util::Pcg::new(23);
    let rhs: Vec<f64> = (0..n * n * n).map(|_| rng.gen_f64() - 0.5).collect();
    let x0 = vec![0.0f64; g * g * g];
    let r0 = rhs.iter().map(|v| v * v).sum::<f64>().sqrt();
    // several smoother applications
    let mut x = x0;
    for _ in 0..6 {
        let out = rt.call_f32("hpcg_symgs", &[&x, &rhs])?.remove(0);
        // re-pad
        let mut xp = vec![0.0f64; g * g * g];
        for z in 0..n {
            for y in 0..n {
                for xx in 0..n {
                    xp[((z + 1) * g + y + 1) * g + xx + 1] =
                        out[(z * n + y) * n + xx];
                }
            }
        }
        x = xp;
    }
    let ax = rt.call_f32("hpcg_spmv", &[&x])?.remove(0);
    let r1 = rhs
        .iter()
        .zip(&ax)
        .map(|(b, a)| (b - a) * (b - a))
        .sum::<f64>()
        .sqrt();
    Ok((r0, r1))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIG19_NODES: [usize; 5] = [128, 512, 2048, 4096, 8192];

    #[test]
    fn step_world_couples_halo_and_residual_allreduce() {
        use crate::machine::Machine;
        use crate::mpi::World;
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut wd = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let td = step_world(&mut wd, 12, 1 << 20);
        // 2 V-cycles, each gated by its 50us smoothing interval
        assert!(td > 100e-6, "{td}");
        // the 12-rank tree allreduce leaves remainder-rank round gaps
        // (ranks 4..7 idle between halo and the second doubling round),
        // so the flush takes the materialized fallback — exact, just
        // not windowed (see EXPERIMENTS.md §Streaming)
        let fs = wd.last_flush.expect("superstep flushed");
        assert!(!fs.streamed, "gap-ridden flush must fall back");
        assert_eq!(fs.late_releases, 0);
        let mut wd2 = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let td2 = step_world(&mut wd2, 12, 1 << 20);
        assert!((td - td2).abs() < 1e-12, "deterministic: {td} vs {td2}");
        let mut wa = World::new(&m.topo, m.place_job(0, 12, 1));
        assert!(step_world(&mut wa, 12, 1 << 20) > 0.0);
    }

    #[test]
    fn fom_scales_to_8192_nodes() {
        let cfg = AuroraConfig::aurora();
        let pts = fig19(&cfg, &FIG19_NODES);
        // efficiency stays high but decays with MLMG sync depth
        for p in &pts {
            assert!(p.efficiency > 0.80, "{} nodes {}", p.nodes, p.efficiency);
        }
        assert!(pts.last().unwrap().efficiency < pts[0].efficiency + 1e-9);
    }

    #[test]
    fn step_dag_runs_closed_loop() {
        use crate::fabric::des::{DesOpts, DesSim};
        let topo = Topology::new(&AuroraConfig::small(4, 4));
        let mut router = Router::new(&topo);
        let dag = step_dag(&topo, &mut router, 16, 1 << 20);
        // per cycle: 16 compute + halo (16 x 2) + 4 doubling rounds x 16
        assert_eq!(dag.len(), 2 * (16 + 32 + 4 * 16));
        let res = DesSim::new(&topo, DesOpts::default()).run_dag(&dag);
        // two smoothing intervals are serialized by the dependency chain
        assert!(res.makespan > 100e-6, "{}", res.makespan);
    }

    #[test]
    fn fom_magnitude_is_plausible() {
        // billions of cells per second at scale
        let cfg = AuroraConfig::aurora();
        let pts = fig19(&cfg, &[8192]);
        assert!(pts[0].fom > 100.0, "{} B cells/s", pts[0].fom);
    }

    #[test]
    fn mg_sync_is_the_scaling_tax() {
        let cfg = AuroraConfig::aurora();
        let t_small = step_time(&cfg, 128);
        let t_big = step_time(&cfg, 8192);
        assert!(t_big > t_small, "sync depth must grow");
        assert!(t_big < t_small * 1.25, "but stay within ~20%");
    }
}
