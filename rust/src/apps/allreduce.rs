//! MPI_Allreduce latency benchmark (paper §5.1, Fig 14): latency vs node
//! count (up to 2,048) for message sizes 8 B - 16 MiB, GPU buffers.
//!
//! "Less than linear latency growth is observed, which is typical for a
//! recursive-doubling tree algorithm. A switch from a ring algorithm to a
//! tree algorithm is clearly seen on the curves."

use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};

#[derive(Debug, Clone)]
pub struct AllreducePoint {
    pub nodes: usize,
    pub msg_bytes: u64,
    pub latency: f64,
    /// Which algorithm the runtime picked (the Fig 14 kink).
    pub algorithm: &'static str,
}

/// Sweep node counts x message sizes. PPN 1 with GPU buffers, matching
/// the Fig 14 setup ("buffers located in GPU memory").
pub fn sweep(machine: &Machine, node_counts: &[usize], sizes: &[u64])
    -> Vec<AllreducePoint> {
    let mut out = Vec::new();
    for &nodes in node_counts {
        for &size in sizes {
            let mut w = World::new(
                &machine.topo,
                machine.place_job(0, nodes, 1),
            )
            .gpu_buffers();
            let comm = Comm::world(nodes);
            let latency = coll::allreduce(&mut w, &comm, size);
            let algorithm = if size <= machine.cfg.allreduce_tree_cutoff {
                "tree"
            } else {
                "ring"
            };
            out.push(AllreducePoint { nodes, msg_bytes: size, latency,
                                      algorithm });
        }
    }
    out
}

/// The Fig 14 grid (scaled to the machine under test).
pub fn fig14_nodes(machine: &Machine) -> Vec<usize> {
    [2usize, 8, 32, 128, 512, 2048]
        .into_iter()
        .filter(|&n| n <= machine.cfg.nodes())
        .collect()
}

pub fn fig14_sizes() -> Vec<u64> {
    vec![8, 1 << 10, 64 << 10, 1 << 20, 16 << 20]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    fn machine() -> Machine {
        Machine::new(&AuroraConfig::small(16, 8)) // 256 nodes
    }

    #[test]
    fn latency_grows_sublinearly_for_small_messages() {
        let m = machine();
        let pts = sweep(&m, &[4, 16, 64, 256], &[8]);
        let lat: Vec<f64> = pts.iter().map(|p| p.latency).collect();
        // 64x more nodes must cost far less than 64x the latency
        assert!(
            lat[3] < lat[0] * 8.0,
            "tree allreduce should be ~log-depth: {lat:?}"
        );
        // but latency does grow with node count
        assert!(lat[3] > lat[0]);
    }

    #[test]
    fn algorithm_switch_visible() {
        let m = machine();
        let cutoff = m.cfg.allreduce_tree_cutoff;
        let pts = sweep(&m, &[64], &[cutoff, cutoff * 4]);
        assert_eq!(pts[0].algorithm, "tree");
        assert_eq!(pts[1].algorithm, "ring");
    }

    #[test]
    fn small_allreduce_latency_band() {
        // Fig 14: 8 B allreduce at moderate scale sits in the tens of
        // microseconds
        let m = machine();
        let pts = sweep(&m, &[64], &[8]);
        let l = pts[0].latency;
        assert!(l > 5e-6 && l < 200e-6, "latency {l}");
    }

    #[test]
    fn large_messages_cost_bandwidth() {
        let m = machine();
        let pts = sweep(&m, &[16], &[8, 16 << 20]);
        assert!(pts[1].latency > pts[0].latency * 100.0);
    }
}
