//! FMM one-sided communication study (paper §5.3.5, Tables 4-6).
//!
//! The NWChemEx Fast Multipole Method issues massive numbers of sparse
//! MPI_Get/MPI_Put requests with constantly flipping sender/receiver
//! roles. The paper's four configurations (Table 4) drive the software
//! RMA path of `mpi::rma`; we regenerate Tables 5 and 6 (transfer time
//! with/without HMEM) and the 9x16 sub-communicator cliff.

use crate::machine::Machine;
use crate::mpi::rma::{run_with_fences, RmaKind, RmaOp, WindowSim};
use crate::mpi::{Comm, World};
use crate::util::Pcg;
use anyhow::Result;

/// Table 4 configurations: (label, nodes, ranks-per-comm, sub-comms,
/// total messages).
pub const TABLE4: [(&str, usize, usize, usize, u64); 4] = [
    ("1 x 8", 1, 8, 1, 1_615_459),
    ("1 x 16", 1, 16, 1, 2_127_199),
    ("1 x 32", 1, 32, 1, 2_776_246),
    ("9 x 16", 9, 144, 9, 19_201_665),
];

/// Elements per one-sided message (sparse multipole data).
pub const MSG_ELEMS: usize = 16;

#[derive(Debug, Clone)]
pub struct FmmRow {
    pub label: &'static str,
    pub messages: u64,
    pub time: f64,
}

/// Generate the FMM request pattern for one sub-communicator: every rank
/// issues gets/puts to sparse offsets on the other ranks, roles flipping.
fn gen_ops(kind: RmaKind, ranks: usize, total_msgs: u64, seed: u64,
           win_len: usize) -> Vec<RmaOp> {
    let mut rng = Pcg::new(seed);
    let mut ops = Vec::with_capacity(total_msgs as usize);
    for k in 0..total_msgs {
        let origin = (k as usize) % ranks;
        let mut target = rng.gen_usize(ranks);
        if target == origin {
            target = (target + 1) % ranks;
        }
        let offset = rng.gen_usize(win_len - MSG_ELEMS);
        ops.push(RmaOp { kind, origin, target, offset, len: MSG_ELEMS });
    }
    ops
}

/// Run one Table 5/6 configuration. `scale` divides the message count so
/// the unit-test path stays fast (1.0 = paper-exact counts).
pub fn run_config(machine: &Machine, cfg_row: usize, kind: RmaKind,
                  hmem: bool, scale: f64) -> Result<FmmRow> {
    let (label, nodes, ranks, subcomms, msgs) = TABLE4[cfg_row];
    let msgs_scaled = ((msgs as f64 * scale) as u64).max(1);
    let ppn = (ranks / subcomms).min(16).max(1);
    let ranks_per_sub = ranks / subcomms;
    let nodes_used = nodes.max(1);
    let mut w = World::new(
        &machine.topo,
        machine.place_job(0, nodes_used, (ranks + nodes_used - 1) / nodes_used),
    );
    let _ = ppn;
    let world_comm = Comm::world(ranks);
    // sub-communicators interleave across nodes (round-robin color), so
    // multi-node configs pay the inter-node software-RMA tax the paper's
    // 9x16 row exposes
    let subs = if subcomms > 1 {
        world_comm.split(|i| i % subcomms)
    } else {
        world_comm.split(|i| i / ranks_per_sub)
    };

    // fence cadence the paper converged on
    let fence_every = if kind == RmaKind::Put && !hmem {
        100
    } else {
        2000
    };

    let mut t_max: f64 = 0.0;
    let msgs_per_sub = msgs_scaled / subcomms as u64;
    for (si, sub) in subs.iter().enumerate() {
        let ops = gen_ops(kind, sub.size(), msgs_per_sub, si as u64 + 1,
                          512);
        let mut win = WindowSim::new(sub.size(), 512, hmem);
        let t = run_with_fences(&mut w, sub, &mut win, &ops, fence_every)?;
        t_max = t_max.max(t);
    }
    Ok(FmmRow { label, messages: msgs_scaled, time: t_max / scale.min(1.0) })
}

/// Regenerate Table 5 (Get) or Table 6 (Put) at reduced message scale;
/// times are extrapolated back to the paper's counts.
pub fn table(machine: &Machine, kind: RmaKind, hmem: bool, scale: f64)
    -> Result<Vec<FmmRow>> {
    let rows = match kind {
        RmaKind::Get => {
            if hmem {
                vec![0, 1, 2, 3]
            } else {
                vec![0, 1, 2] // paper: 9x16 without HMEM is "NA"
            }
        }
        RmaKind::Put => vec![0, 1, 2],
    };
    rows.into_iter()
        .map(|r| run_config(machine, r, kind, hmem, scale))
        .collect()
}

/// Functional data-integrity check: a ring of gets moves the right data.
pub fn functional(machine: &Machine) -> Result<bool> {
    let ranks = 8;
    let mut w = World::new(&machine.topo, machine.place_job(0, 1, ranks));
    let comm = Comm::world(ranks);
    let mut win = WindowSim::new(ranks, 64, true);
    for r in 0..ranks {
        win.data[r] = (0..64).map(|i| (r * 100 + i) as f64).collect();
    }
    // every rank gets the first 32 elements of its right neighbour
    let ops: Vec<RmaOp> = (0..ranks)
        .map(|r| RmaOp {
            kind: RmaKind::Get,
            origin: r,
            target: (r + 1) % ranks,
            offset: 0,
            len: 32,
        })
        .collect();
    win.run_phase(&mut w, &comm, &ops)?;
    win.fence(&mut w, &comm);
    Ok((0..ranks).all(|r| {
        let want = (((r + 1) % ranks) * 100) as f64;
        win.data[r][0] == want && win.data[r][31] == want + 31.0
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    fn machine() -> Machine {
        Machine::new(&AuroraConfig::small(4, 8))
    }

    const SCALE: f64 = 0.02; // 2% of the paper's message counts per test

    #[test]
    fn table5_get_with_hmem_seconds_band() {
        // paper: 0.9 / 1.1 / 1.6 s for rows 1-3
        let m = machine();
        let rows = table(&m, RmaKind::Get, true, SCALE).unwrap();
        let paper = [0.9, 1.1, 1.6, 14.5];
        for (row, want) in rows.iter().zip(paper) {
            let ratio = row.time / want;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: {}s vs paper {want}s",
                row.label,
                row.time
            );
        }
    }

    #[test]
    fn get_without_hmem_order_of_magnitude_slower() {
        let m = machine();
        let with = run_config(&m, 0, RmaKind::Get, true, SCALE).unwrap();
        let without = run_config(&m, 0, RmaKind::Get, false, SCALE).unwrap();
        let speedup = without.time / with.time;
        assert!((8.0..50.0).contains(&speedup), "HMEM speedup {speedup}");
    }

    #[test]
    fn get_without_hmem_improves_with_more_ranks() {
        // Table 5 shape: 24.6 -> 17.1 -> 13.0 s as ranks grow
        let m = machine();
        let r8 = run_config(&m, 0, RmaKind::Get, false, SCALE).unwrap();
        let r32 = run_config(&m, 2, RmaKind::Get, false, SCALE).unwrap();
        assert!(
            r32.time < r8.time,
            "origin-serialized gets parallelize: {} vs {}",
            r8.time,
            r32.time
        );
    }

    #[test]
    fn put_order_of_magnitude_slower_than_get() {
        let m = machine();
        let g = run_config(&m, 0, RmaKind::Get, true, SCALE).unwrap();
        let p = run_config(&m, 0, RmaKind::Put, true, SCALE).unwrap();
        let ratio = p.time / g.time;
        assert!((8.0..25.0).contains(&ratio), "put/get {ratio}");
    }

    #[test]
    fn hmem_helps_put_only_2x() {
        let m = machine();
        let with = run_config(&m, 0, RmaKind::Put, true, SCALE).unwrap();
        let without = run_config(&m, 0, RmaKind::Put, false, SCALE).unwrap();
        let speedup = without.time / with.time;
        assert!((1.5..4.0).contains(&speedup), "put speedup {speedup}");
    }

    #[test]
    fn subcommunicator_cliff() {
        // Table 5 row 4: 9x16 is an order of magnitude off the intra-node
        // per-message rate
        let m = machine();
        let intra = run_config(&m, 1, RmaKind::Get, true, SCALE).unwrap();
        let multi = run_config(&m, 3, RmaKind::Get, true, SCALE * 0.2).unwrap();
        let rate_intra = intra.messages as f64 / intra.time;
        let rate_multi =
            multi.messages as f64 / (multi.time * 0.2 / SCALE.min(1.0));
        // per-message throughput collapses by ~an order of magnitude
        let drop = rate_intra / rate_multi.max(1.0);
        assert!(drop > 4.0, "drop {drop}");
    }

    #[test]
    fn functional_ring_moves_data() {
        let m = machine();
        assert!(functional(&m).unwrap());
    }
}
