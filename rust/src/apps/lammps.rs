//! LAMMPS Rhodopsin benchmark (paper §5.3.4, Fig 20): all-atom protein in
//! solvated lipid bilayer — CHARMM + PPPM long-range electrostatics +
//! SHAKE; 254 billion atoms on 9,216 nodes (PPN 96, 96^3 process grid),
//! >85% weak-scaling efficiency vs the 128-node baseline.
//!
//! Per step: pair forces over 4x6x4 spatial bins (the `lammps_pair_tile`
//! artifact), neighbour halo exchange, and the PPPM charge-grid 3D FFT
//! (the same transpose-bound pattern as HACC's long-range solve).

use crate::config::AuroraConfig;
use crate::fabric::analytic;
use crate::fabric::workload::{self, DagBuilder, DagWorkload};
use crate::fabric::Router;
use crate::machine::Machine;
use crate::runtime::{Engine, NodeRoofline, Runtime};
use crate::topology::Topology;
use anyhow::Result;

pub use super::ScalingPoint;

pub const PPN: usize = 96;
/// Atoms per node in the weak-scaling series (254e9 / 9216 nodes).
pub const ATOMS_PER_NODE: f64 = 27.6e6;

/// One MD step time at `nodes`.
pub fn step_time(cfg: &AuroraConfig, nodes: usize) -> f64 {
    let rl = NodeRoofline::new(cfg);
    // pair forces: ~ 1,100 flops/atom/step with CHARMM cutoffs + neighbor
    // list reuse (the 4x6x4 binning keeps tiles dense)
    let t_pair =
        rl.node_time(Engine::Fp64, ATOMS_PER_NODE * 1100.0 * 0.25,
                     ATOMS_PER_NODE * 200.0);
    // SHAKE + integration: memory bound
    let t_integrate =
        rl.node_time(Engine::MemoryBound, 0.0, ATOMS_PER_NODE * 150.0);
    // neighbour halo: skin exchange with 6 neighbours
    let halo_bytes = ATOMS_PER_NODE * 0.10 * 48.0;
    let t_halo = halo_bytes
        / (cfg.nic_eff_bw_host * cfg.nics_per_node as f64)
        + 6.0 * cfg.mpi_overhead;
    // PPPM: charge grid ~ 1 point / 2 atoms, two 3D-FFT transposes
    let grid_bytes = ATOMS_PER_NODE / 2.0 * 8.0;
    let a2a_bw = analytic::alltoall_aggregate_bw(cfg, nodes, 16, 128 << 10)
        / nodes as f64;
    let t_pppm = 4.0 * grid_bytes / a2a_bw;
    // global thermo reductions
    let ranks = (nodes * PPN) as f64;
    let t_sync = 4.0 * 10.0e-6 * ranks.log2();
    t_pair + t_integrate + t_halo + t_pppm + t_sync
}

/// Closed-loop LAMMPS MD-step trace (§5.3.4) as a dependency workload:
/// neighbour-skin halo exchange (±1/±2 in the 1-D embedding), the pair
/// force + SHAKE compute interval, then the PPPM charge-grid transpose
/// (pairwise all2all of grid_bytes / ranks). Dependency release couples
/// the phases: a congested halo delays PPPM, exactly the closed-loop
/// effect §6 observes at scale.
pub fn step_dag(
    topo: &Topology,
    router: &mut Router,
    ranks: usize,
    grid_bytes: u64,
) -> DagWorkload {
    let nics = workload::spread_nics(topo, ranks);
    let mut b = DagBuilder::new();
    let halo =
        vec![workload::neighbor_round(&nics, &[-2, -1, 1, 2],
                                      (grid_bytes / 16).max(1))];
    workload::push_rounds(&mut b, router, &halo, 0.0);
    for &nic in &nics {
        b.compute(nic, 150e-6); // pair forces + SHAKE
    }
    let chunk = (grid_bytes / ranks.max(1) as u64).max(1);
    let pppm = workload::pairwise_rounds(&nics, chunk);
    workload::push_rounds(&mut b, router, &pppm, 0.0);
    b.finish()
}

/// Drive one LAMMPS MD step through the MPI [`World`] as
/// dependency-released supersteps: the neighbour-skin halo, the pair
/// force + SHAKE compute interval, then the PPPM charge-grid pairwise
/// transpose — on `FabricTier::Des` the whole step prices as one
/// closed-loop DAG (`World::begin_superstep`), so a congested halo
/// delays PPPM exactly as §6 observes at scale. Returns the elapsed
/// span.
pub fn step_world(
    w: &mut crate::mpi::World,
    ranks: usize,
    grid_bytes: u64,
) -> f64 {
    assert!(w.size() >= ranks, "world too small for {ranks} ranks");
    let t0 = w.elapsed();
    w.begin_superstep();
    let skin = (grid_bytes / 16).max(1);
    w.exchange(&super::rank_halo_round(ranks, &[-2, -1, 1, 2], skin));
    for r in 0..ranks {
        // staged compute node: serializes after the rank's halo and
        // gates its PPPM rounds in the priced DAG
        w.superstep_compute(r, 150e-6); // pair forces + SHAKE
    }
    let chunk = (grid_bytes / ranks.max(1) as u64).max(1);
    for shift in 1..ranks {
        w.exchange(&super::rank_pairwise_round(ranks, shift, chunk));
    }
    w.end_superstep();
    w.elapsed() - t0
}

/// Fig 20: weak-scaling times + efficiencies, 128 -> 9,216 nodes.
pub fn fig20(cfg: &AuroraConfig, node_counts: &[usize]) -> Vec<ScalingPoint> {
    let pts: Vec<(usize, f64)> = node_counts
        .iter()
        .map(|&nodes| (nodes, step_time(cfg, nodes)))
        .collect();
    super::weak_efficiency_from_times(&pts)
}

pub const FIG20_NODES: [usize; 5] = [128, 1024, 4096, 8192, 9216];

/// Functional demo: pair-force tile through the artifact conserves
/// momentum and respects the cutoff. Returns (net-force ratio, max |F|).
pub fn functional(rt: &mut Runtime, _machine: &Machine) -> Result<(f64, f64)> {
    // jittered grid positions (128 atoms, matching the artifact shape)
    let mut rng = crate::util::Pcg::new(31);
    let mut pos = Vec::with_capacity(128 * 3);
    for i in 0..128 {
        let base = [
            (i % 5) as f64,
            ((i / 5) % 5) as f64,
            (i / 25) as f64,
        ];
        for b in base {
            pos.push(b + 0.1 * (rng.gen_f64() - 0.5));
        }
    }
    let f = rt.call_f32("lammps_pair_tile", &[&pos])?.remove(0);
    let mut net = [0.0f64; 3];
    let mut maxf: f64 = 0.0;
    for i in 0..128 {
        for d in 0..3 {
            net[d] += f[i * 3 + d];
            maxf = maxf.max(f[i * 3 + d].abs());
        }
    }
    let ratio =
        net.iter().map(|v| v.abs()).fold(0.0, f64::max) / maxf.max(1e-12);
    Ok((ratio, maxf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_over_85_percent_at_9216() {
        let cfg = AuroraConfig::aurora();
        let pts = fig20(&cfg, &FIG20_NODES);
        let last = pts.last().unwrap();
        assert!(
            last.efficiency > 0.85,
            "9216-node eff {}",
            last.efficiency
        );
        // and it does decay vs baseline
        assert!(last.efficiency < 1.0);
    }

    #[test]
    fn efficiency_monotonically_decays() {
        let cfg = AuroraConfig::aurora();
        let pts = fig20(&cfg, &FIG20_NODES);
        for w in pts.windows(2) {
            assert!(
                w[1].efficiency <= w[0].efficiency + 1e-9,
                "{:?}",
                pts.iter().map(|p| p.efficiency).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn step_world_runs_closed_loop() {
        use crate::machine::Machine;
        use crate::mpi::World;
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut wd = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let td = step_world(&mut wd, 12, 8 << 20);
        assert!(td > 150e-6, "compute must gate PPPM: {td}");
        // halo / compute / pairwise rounds all re-touch every rank, so
        // the superstep flush streams on the windowed executor
        let fs = wd.last_flush.expect("superstep flushed");
        assert!(fs.streamed, "exchange-loop flush must stream");
        assert_eq!(fs.late_releases, 0);
        let mut wd2 = World::new(&m.topo, m.place_job(0, 12, 1)).des_fabric();
        let td2 = step_world(&mut wd2, 12, 8 << 20);
        assert!((td - td2).abs() < 1e-12, "{td} vs {td2}");
    }

    #[test]
    fn step_dag_phases_serialize() {
        use crate::fabric::des::{DesOpts, DesSim};
        let topo = Topology::new(&AuroraConfig::small(4, 4));
        let mut router = Router::new(&topo);
        let dag = step_dag(&topo, &mut router, 12, 8 << 20);
        // halo (12 x 4) + 12 compute + pppm (11 rounds x 12)
        assert_eq!(dag.len(), 48 + 12 + 132);
        let res = DesSim::new(&topo, DesOpts::default()).run_dag(&dag);
        assert!(res.makespan > 150e-6, "{}", res.makespan);
        // the pppm transfers all finish after the compute interval
        let cp_end = res.node_finish[48..60]
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        assert!(res.makespan > cp_end);
    }

    #[test]
    fn total_atoms_match_paper() {
        // 254 billion atoms across 9,216 nodes
        let total = ATOMS_PER_NODE * 9216.0;
        assert!((total / 254e9 - 1.0).abs() < 0.01, "{total}");
    }
}
