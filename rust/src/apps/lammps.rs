//! LAMMPS Rhodopsin benchmark (paper §5.3.4, Fig 20): all-atom protein in
//! solvated lipid bilayer — CHARMM + PPPM long-range electrostatics +
//! SHAKE; 254 billion atoms on 9,216 nodes (PPN 96, 96^3 process grid),
//! >85% weak-scaling efficiency vs the 128-node baseline.
//!
//! Per step: pair forces over 4x6x4 spatial bins (the `lammps_pair_tile`
//! artifact), neighbour halo exchange, and the PPPM charge-grid 3D FFT
//! (the same transpose-bound pattern as HACC's long-range solve).

use crate::config::AuroraConfig;
use crate::fabric::analytic;
use crate::machine::Machine;
use crate::runtime::{Engine, NodeRoofline, Runtime};
use anyhow::Result;

pub use super::ScalingPoint;

pub const PPN: usize = 96;
/// Atoms per node in the weak-scaling series (254e9 / 9216 nodes).
pub const ATOMS_PER_NODE: f64 = 27.6e6;

/// One MD step time at `nodes`.
pub fn step_time(cfg: &AuroraConfig, nodes: usize) -> f64 {
    let rl = NodeRoofline::new(cfg);
    // pair forces: ~ 1,100 flops/atom/step with CHARMM cutoffs + neighbor
    // list reuse (the 4x6x4 binning keeps tiles dense)
    let t_pair =
        rl.node_time(Engine::Fp64, ATOMS_PER_NODE * 1100.0 * 0.25,
                     ATOMS_PER_NODE * 200.0);
    // SHAKE + integration: memory bound
    let t_integrate =
        rl.node_time(Engine::MemoryBound, 0.0, ATOMS_PER_NODE * 150.0);
    // neighbour halo: skin exchange with 6 neighbours
    let halo_bytes = ATOMS_PER_NODE * 0.10 * 48.0;
    let t_halo = halo_bytes
        / (cfg.nic_eff_bw_host * cfg.nics_per_node as f64)
        + 6.0 * cfg.mpi_overhead;
    // PPPM: charge grid ~ 1 point / 2 atoms, two 3D-FFT transposes
    let grid_bytes = ATOMS_PER_NODE / 2.0 * 8.0;
    let a2a_bw = analytic::alltoall_aggregate_bw(cfg, nodes, 16, 128 << 10)
        / nodes as f64;
    let t_pppm = 4.0 * grid_bytes / a2a_bw;
    // global thermo reductions
    let ranks = (nodes * PPN) as f64;
    let t_sync = 4.0 * 10.0e-6 * ranks.log2();
    t_pair + t_integrate + t_halo + t_pppm + t_sync
}

/// Fig 20: weak-scaling times + efficiencies, 128 -> 9,216 nodes.
pub fn fig20(cfg: &AuroraConfig, node_counts: &[usize]) -> Vec<ScalingPoint> {
    let pts: Vec<(usize, f64)> = node_counts
        .iter()
        .map(|&nodes| (nodes, step_time(cfg, nodes)))
        .collect();
    super::weak_efficiency_from_times(&pts)
}

pub const FIG20_NODES: [usize; 5] = [128, 1024, 4096, 8192, 9216];

/// Functional demo: pair-force tile through the artifact conserves
/// momentum and respects the cutoff. Returns (net-force ratio, max |F|).
pub fn functional(rt: &mut Runtime, _machine: &Machine) -> Result<(f64, f64)> {
    // jittered grid positions (128 atoms, matching the artifact shape)
    let mut rng = crate::util::Pcg::new(31);
    let mut pos = Vec::with_capacity(128 * 3);
    for i in 0..128 {
        let base = [
            (i % 5) as f64,
            ((i / 5) % 5) as f64,
            (i / 25) as f64,
        ];
        for b in base {
            pos.push(b + 0.1 * (rng.gen_f64() - 0.5));
        }
    }
    let f = rt.call_f32("lammps_pair_tile", &[&pos])?.remove(0);
    let mut net = [0.0f64; 3];
    let mut maxf: f64 = 0.0;
    for i in 0..128 {
        for d in 0..3 {
            net[d] += f[i * 3 + d];
            maxf = maxf.max(f[i * 3 + d].abs());
        }
    }
    let ratio =
        net.iter().map(|v| v.abs()).fold(0.0, f64::max) / maxf.max(1e-12);
    Ok((ratio, maxf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_over_85_percent_at_9216() {
        let cfg = AuroraConfig::aurora();
        let pts = fig20(&cfg, &FIG20_NODES);
        let last = pts.last().unwrap();
        assert!(
            last.efficiency > 0.85,
            "9216-node eff {}",
            last.efficiency
        );
        // and it does decay vs baseline
        assert!(last.efficiency < 1.0);
    }

    #[test]
    fn efficiency_monotonically_decays() {
        let cfg = AuroraConfig::aurora();
        let pts = fig20(&cfg, &FIG20_NODES);
        for w in pts.windows(2) {
            assert!(
                w[1].efficiency <= w[0].efficiency + 1e-9,
                "{:?}",
                pts.iter().map(|p| p.efficiency).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn total_atoms_match_paper() {
        // 254 billion atoms across 9,216 nodes
        let total = ATOMS_PER_NODE * 9216.0;
        assert!((total / 254e9 - 1.0).abs() < 0.01, "{total}");
    }
}
