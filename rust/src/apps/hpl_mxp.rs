//! HPL-MxP (paper §5.2.2, Fig 16): 11.64 EF/s on 9,500 nodes — #1 in the
//! world at SC24 submission.
//!
//! The LU factorization runs in FP16/FP32 on the matrix engines (our
//! bf16 x bf16 -> f32 Pallas kernel `mxp_gemm`); iterative refinement
//! runs in FP64. [`performance`] models the mixed-precision factor +
//! FP64 IR phases; [`functional`] demonstrates the MxP core claim on real
//! numerics: a low-precision factorization refined to FP64 accuracy via
//! the AOT artifacts.

use crate::config::AuroraConfig;
use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};
use crate::runtime::{NodeRoofline, Runtime};
use anyhow::Result;

pub use super::hpl::CurvePoint;

#[derive(Debug, Clone)]
pub struct MxpRun {
    pub nodes: usize,
    pub n: u64,
    pub time: f64,
    /// HPL-MxP score: the FP64-equivalent rate (2/3 N^3 over wall time).
    pub rate: f64,
    pub factor_time: f64,
    pub ir_time: f64,
    pub curve: Vec<CurvePoint>,
}

/// HPL-MxP performance model. The score counts the same 2/3 N^3 flops as
/// HPL but executed at mixed precision, plus the IR iterations.
pub fn performance(cfg: &AuroraConfig, nodes: usize) -> MxpRun {
    let rl = NodeRoofline::new(cfg);
    // fp16/bf16 storage: twice the N per byte vs FP64
    let bytes = 0.72 * nodes as f64 * cfg.hbm_per_node_gb * 1e9;
    let n = ((bytes / 4.0).sqrt() as u64) / 2048 * 2048;
    let nb: u64 = 4096;
    let mxp = nodes as f64 * rl.mxp_rate();
    let alpha = 12.0e-6;
    let beta = cfg.nic_eff_bw_host * cfg.nics_per_node as f64;
    let (p, q) = super::hpl::process_grid(nodes);
    let overlap = 0.35;
    let panel_eff = 0.06;

    let iters = (n / nb) as usize;
    let mut t = 0.0;
    let mut curve = Vec::new();
    let sample_every = (iters / 160).max(1);
    for j in 0..iters {
        let rem = (n - j as u64 * nb) as f64;
        let f_update = 2.0 * nb as f64 * rem * rem;
        let t_update = f_update / mxp;
        let f_panel = nb as f64 * nb as f64 * (rem / p as f64);
        let t_panel = f_panel / (rl.mxp_rate() * panel_eff);
        // half the bytes of FP64 HPL: bf16/fp16 panels
        let t_bcast = (q as f64).log2()
            * (alpha + rem / p as f64 * nb as f64 * 2.0 / beta);
        let t_swap = (p as f64).log2()
            * (alpha + rem / q as f64 * nb as f64 * 2.0 / beta);
        let dt = t_update + (t_panel + t_bcast + t_swap) * (1.0 - overlap);
        t += dt;
        if j % sample_every == 0 {
            curve.push(CurvePoint { t, rate: f_update / dt });
        }
    }
    let factor_time = t;
    // FP64 IR: a few matrix sweeps (memory bound over bf16 storage) +
    // triangular solves + reduction latencies
    let ir_flops = 6.0 * (n as f64) * (n as f64);
    let ir_bytes = 3.0 * (n as f64) * (n as f64) * 2.0;
    let ir_time = (ir_flops / (nodes as f64 * rl.gemm_rate() * 0.2))
        .max(ir_bytes / (nodes as f64 * rl.hbm_bw))
        + 24.0 * alpha * (q as f64).log2();
    t += ir_time;
    curve.push(CurvePoint { t, rate: 0.1 * mxp });
    let rate = 2.0 / 3.0 * (n as f64).powi(3) / t;
    MxpRun { nodes, n, time: t, rate, factor_time, ir_time, curve }
}

/// Functional MxP: a low-precision factorization (the bf16 update path
/// validated through the `mxp_update` artifact) refined to FP64 accuracy
/// with `mxp_ir_step`. Returns (r0, r_final, IR iterations, sim time).
pub fn functional(rt: &mut Runtime, machine: &Machine)
    -> Result<(f64, f64, usize, f64)> {
    const N: usize = 256;
    let mut w = World::new(&machine.topo, machine.place_job(0, 4, 1));
    let comm = Comm::world(4);

    let mut rng = crate::util::Pcg::new(11);
    let mut a = vec![0.0f64; N * N];
    for v in a.iter_mut() {
        *v = rng.gen_f64() - 0.5;
    }
    for i in 0..N {
        a[i * N + i] += N as f64;
    }
    let xtrue: Vec<f64> =
        (0..N).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
    let b: Vec<f64> = (0..N)
        .map(|i| (0..N).map(|j| a[i * N + j] * xtrue[j]).sum())
        .collect();

    // bf16 tile-update sanity through the Pallas artifact
    let c = vec![0.0f64; 128 * 128];
    let a_t = vec![0.5f64; 128 * 64];
    let b_t = vec![0.25f64; 64 * 128];
    let upd = rt.call_f32("mxp_update", &[&a_t, &b_t, &c])?.remove(0);
    anyhow::ensure!((upd[0] + 8.0).abs() < 0.1, "mxp tile sanity: {}", upd[0]);

    // f32 unpivoted LU as the low-precision factor proxy
    let mut lu32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
    for k in 0..N {
        for i in k + 1..N {
            let m = lu32[i * N + k] / lu32[k * N + k];
            lu32[i * N + k] = m;
            for j in k + 1..N {
                lu32[i * N + j] -= m * lu32[k * N + j];
            }
        }
    }
    let lp_solve = |rhs: &[f64]| -> Vec<f64> {
        let mut y: Vec<f32> = rhs.iter().map(|&v| v as f32).collect();
        for i in 0..N {
            for j in 0..i {
                y[i] -= lu32[i * N + j] * y[j];
            }
        }
        for i in (0..N).rev() {
            for j in i + 1..N {
                y[i] -= lu32[i * N + j] * y[j];
            }
            y[i] /= lu32[i * N + i];
        }
        y.into_iter().map(|v| v as f64).collect()
    };

    // FP64 IR via the mxp_ir_step artifact + allreduce of norms
    let mut x = lp_solve(&b);
    let r0 = rt.call_f64("mxp_ir_step", &[&a, &x, &b])?[1][0];
    let mut iters = 0;
    let mut rn = r0;
    while rn > 1e-10 * r0.max(1.0) && iters < 40 {
        let out = rt.call_f64("mxp_ir_step", &[&a, &x, &b])?;
        rn = out[1][0];
        let dx = lp_solve(&out[0]);
        for i in 0..N {
            x[i] += dx[i];
        }
        coll::allreduce(&mut w, &comm, 8); // residual-norm agreement
        iters += 1;
    }
    let rfinal = rt.call_f64("mxp_ir_step", &[&a, &x, &b])?[1][0];
    Ok((r0, rfinal, iters, w.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_rate() {
        // Fig 16: 11.64 EF/s on 9,500 nodes
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 9500);
        let ef = run.rate / 1e18;
        assert!((ef - 11.64).abs() / 11.64 < 0.08, "{ef} EF/s");
    }

    #[test]
    fn mxp_beats_hpl_by_order_of_magnitude() {
        let cfg = AuroraConfig::aurora();
        let mxp = performance(&cfg, 9234).rate;
        let hpl = super::super::hpl::performance(&cfg, 9234).rate;
        let ratio = mxp / hpl;
        assert!((8.0..16.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn ir_phase_is_small_fraction() {
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 9500);
        assert!(run.ir_time < 0.2 * run.factor_time,
            "ir {} factor {}", run.ir_time, run.factor_time);
    }

    #[test]
    fn curve_scales_uniformly() {
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 9500);
        assert!(run.curve.len() > 50);
        let early = run.curve[10].rate;
        let mid = run.curve[run.curve.len() / 2].rate;
        // "performance scaled uniformly across the phases"
        assert!((early / mid - 1.0).abs() < 0.6, "{early} vs {mid}");
    }
}
