//! HPL (paper §5.2.1, Fig 15, Table 2): 1.012 EF/s on 9,234 nodes at
//! 78.84% scaling efficiency, P x Q = 162 x 342, 4h21m54s.
//!
//! Two modes:
//! * [`performance`] — right-looking blocked-LU cost model over the
//!   machine: per-block-column iteration costs (panel factor, row/column
//!   broadcasts, swap, trailing DGEMM update on the roofline), overlap
//!   factor for comm/compute; regenerates the Fig 15 GF/s-vs-time curve
//!   and the Table 2 scaling rows.
//! * [`functional`] — a real 2x2-process-grid blocked LU at N=256 where
//!   every tile operation executes the AOT PJRT artifacts
//!   (`hpl_panel_factor`, `hpl_trsm_row/col`, `hpl_update`) over the
//!   simulated MPI world, validated by the HPL scaled residual.

use crate::config::AuroraConfig;
use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};
use crate::runtime::{NodeRoofline, Runtime};
use anyhow::Result;

/// One Fig 15 sample: elapsed seconds -> instantaneous flop rate.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub t: f64,
    pub rate: f64,
}

#[derive(Debug, Clone)]
pub struct HplRun {
    pub nodes: usize,
    pub n: u64,
    pub p: usize,
    pub q: usize,
    pub time: f64,
    /// Sustained flops/s.
    pub rate: f64,
    /// rate / (nodes * node_fp64_peak) — the Table 2 "Scaling Efficiency".
    pub efficiency: f64,
    pub curve: Vec<CurvePoint>,
}

/// Pick the process grid like the paper: P*Q = 6 ranks/node with
/// Q/P ~ 2.1 (HPL favours wide grids; the paper used 162 x 342 at 9,234
/// nodes, ratio 2.11).
pub fn process_grid(nodes: usize) -> (usize, usize) {
    let ranks = nodes * 6; // one rank per GPU
    let target = 2.11;
    let mut best = (1, ranks);
    let mut best_err = f64::INFINITY;
    let mut p = 1;
    while p * p <= ranks {
        if ranks % p == 0 {
            let q = ranks / p;
            let err = (q as f64 / p as f64 - target).abs();
            if err < best_err {
                best_err = err;
                best = (p, q);
            }
        }
        p += 1;
    }
    best
}

/// Problem size filling `fill` of the HBM (the paper's N for 9,234 nodes
/// back-solves to ~80% of 896 GB/node).
pub fn problem_size(cfg: &AuroraConfig, nodes: usize, fill: f64) -> u64 {
    let bytes = fill * nodes as f64 * cfg.hbm_per_node_gb * 1e9;
    ((bytes / 8.0).sqrt() as u64) / 2048 * 2048
}

/// HPL performance model. `nb` = 2048 (GPU panel width).
pub fn performance(cfg: &AuroraConfig, nodes: usize) -> HplRun {
    let (p, q) = process_grid(nodes);
    let n = problem_size(cfg, nodes, 0.78);
    let nb: u64 = 2048;
    let rl = NodeRoofline::new(cfg);
    let gemm = nodes as f64 * rl.gemm_rate();
    // communication constants (closed-form; the fabric tiers calibrate
    // these in the integration tests)
    let alpha = 12.0e-6; // collective hop latency at scale
    let beta = cfg.nic_eff_bw_host * cfg.nics_per_node as f64; // per node
    // fraction of communication hidden behind the update (lookahead);
    // calibrated so 9,234 nodes land on Table 2's 78.84%
    let overlap = 0.35;
    // panel factorization efficiency (memory-bound, narrow)
    let panel_eff = 0.035;

    let iters = (n / nb) as usize;
    let mut t = 0.0;
    let mut flops_done = 0.0;
    let mut curve = Vec::new();
    let sample_every = (iters / 160).max(1);
    for j in 0..iters {
        let rem = (n - j as u64 * nb) as f64;
        // trailing update: 2 * nb * rem^2 flops across all nodes
        let f_update = 2.0 * nb as f64 * rem * rem;
        let t_update = f_update / gemm;
        // panel: 2/3 nb^3 + nb^2*rem/P on the column, low efficiency
        let f_panel = nb as f64 * nb as f64 * (rem / p as f64);
        let t_panel = f_panel / (rl.gemm_rate() * panel_eff);
        // row broadcast of the panel (binomial over Q)
        let panel_bytes = rem / p as f64 * nb as f64 * 8.0;
        let t_bcast =
            (q as f64).log2() * (alpha + panel_bytes / beta);
        // U swap/broadcast along P
        let row_bytes = rem / q as f64 * nb as f64 * 8.0;
        let t_swap = (p as f64).log2() * (alpha + row_bytes / beta);
        let t_comm = (t_panel + t_bcast + t_swap) * (1.0 - overlap);
        let dt = t_update + t_comm;
        t += dt;
        flops_done += f_update + f_panel;
        if j % sample_every == 0 {
            curve.push(CurvePoint { t, rate: (f_update + f_panel) / dt });
        }
    }
    // final solve + residual check (the Fig 15 tail)
    let t_solve = 2.0 * (n as f64) * (n as f64) / gemm * 50.0;
    t += t_solve;
    curve.push(CurvePoint { t, rate: 0.2 * gemm });
    let total_flops = 2.0 / 3.0 * (n as f64).powi(3);
    let _ = flops_done;
    let rate = total_flops / t;
    HplRun {
        nodes,
        n,
        p,
        q,
        time: t,
        rate,
        efficiency: rate / (nodes as f64 * cfg.node_fp64_peak),
        curve,
    }
}

/// Table 2 node counts from the paper.
pub const TABLE2_NODES: [usize; 9] =
    [9234, 8748, 8632, 8109, 8058, 7200, 6888, 6273, 5439];

pub fn table2(cfg: &AuroraConfig) -> Vec<HplRun> {
    TABLE2_NODES.iter().map(|&n| performance(cfg, n)).collect()
}

// ---------------------------------------------------------------- functional

/// Distributed functional HPL: N=256, nb=64, 2x2 rank grid with
/// block-cyclic tiles, every tile op through PJRT artifacts, comm through
/// the simulated world. Returns (scaled residual, simulated time).
pub fn functional(rt: &mut Runtime, machine: &Machine) -> Result<(f64, f64)> {
    const N: usize = 256;
    const NB: usize = 64;
    const NT: usize = N / NB; // 4x4 tiles
    let mut w = World::new(&machine.topo, machine.place_job(0, 4, 1));
    let comm = Comm::world(4);

    // deterministic diagonally dominant matrix + rhs
    let mut a = vec![0.0f64; N * N];
    let mut rng = crate::util::Pcg::new(7);
    for v in a.iter_mut() {
        *v = rng.gen_f64() - 0.5;
    }
    for i in 0..N {
        a[i * N + i] += N as f64;
    }
    let b: Vec<f64> = (0..N).map(|i| (i % 13) as f64 - 6.0).collect();
    let a0 = a.clone();

    let owner = |bi: usize, bj: usize| -> usize { (bi % 2) * 2 + (bj % 2) };
    let tile = |a: &[f64], bi: usize, bj: usize| -> Vec<f64> {
        let mut t = vec![0.0; NB * NB];
        for r in 0..NB {
            for c in 0..NB {
                t[r * NB + c] = a[(bi * NB + r) * N + bj * NB + c];
            }
        }
        t
    };
    let store = |a: &mut [f64], bi: usize, bj: usize, t: &[f64]| {
        for r in 0..NB {
            for c in 0..NB {
                a[(bi * NB + r) * N + bj * NB + c] = t[r * NB + c];
            }
        }
    };

    for k in 0..NT {
        // 1. panel factor on the diagonal-tile owner
        let diag_owner = owner(k, k);
        let lu = rt.call_f64("hpl_panel_factor", &[&tile(&a, k, k)])?
            .remove(0);
        w.compute(diag_owner, rt.flops("hpl_panel_factor")
            / NodeRoofline::new(&machine.cfg).gemm_rate() * 20.0);
        store(&mut a, k, k, &lu);
        // 2. broadcast the packed LU tile along row and column
        let lu_bytes = (NB * NB * 8) as u64;
        coll::bcast(&mut w, &comm, diag_owner, lu_bytes);
        // 3. U row strip: solve L X = A[k][j]  (artifact takes 128 cols)
        for pair in (k + 1..NT).step_by(2) {
            let cols = (NT - pair).min(2);
            let mut bbuf = vec![0.0f64; NB * 2 * NB];
            for (ci, j) in (pair..pair + cols).enumerate() {
                let t = tile(&a, k, j);
                for r in 0..NB {
                    bbuf[r * 2 * NB + ci * NB..r * 2 * NB + ci * NB + NB]
                        .copy_from_slice(&t[r * NB..r * NB + NB]);
                }
            }
            let x = rt.call_f64("hpl_trsm_row", &[&lu, &bbuf])?.remove(0);
            for (ci, j) in (pair..pair + cols).enumerate() {
                let mut t = vec![0.0; NB * NB];
                for r in 0..NB {
                    t[r * NB..r * NB + NB].copy_from_slice(
                        &x[r * 2 * NB + ci * NB..r * 2 * NB + ci * NB + NB],
                    );
                }
                store(&mut a, k, j, &t);
            }
        }
        // 4. L column strip: solve X U = A[i][k]
        for pair in (k + 1..NT).step_by(2) {
            let rows = (NT - pair).min(2);
            let mut abuf = vec![0.0f64; 2 * NB * NB];
            for (ri, i) in (pair..pair + rows).enumerate() {
                let t = tile(&a, i, k);
                abuf[ri * NB * NB..(ri + 1) * NB * NB].copy_from_slice(&t);
            }
            let x = rt.call_f64("hpl_trsm_col", &[&lu, &abuf])?.remove(0);
            for (ri, i) in (pair..pair + rows).enumerate() {
                store(&mut a, i, k,
                      &x[ri * NB * NB..(ri + 1) * NB * NB].to_vec());
            }
        }
        // panel exchange along the grid
        w.exchange(&[(diag_owner, (diag_owner + 1) % 4, lu_bytes),
                     (diag_owner, (diag_owner + 2) % 4, lu_bytes)]);
        // 5. trailing update per 128x128 super-tile (2x2 tiles)
        let mut si = k + 1;
        while si < NT {
            let bi_n = (NT - si).min(2);
            let mut sj = k + 1;
            while sj < NT {
                let bj_n = (NT - sj).min(2);
                // assemble A (128x64), B (64x128), C (128x128) padded
                let mut abuf = vec![0.0f64; 2 * NB * NB];
                for ri in 0..bi_n {
                    let t = tile(&a, si + ri, k);
                    for r in 0..NB {
                        abuf[(ri * NB + r) * NB..(ri * NB + r + 1) * NB]
                            .copy_from_slice(&t[r * NB..(r + 1) * NB]);
                    }
                }
                let mut bbuf = vec![0.0f64; NB * 2 * NB];
                for ci in 0..bj_n {
                    let t = tile(&a, k, sj + ci);
                    for r in 0..NB {
                        bbuf[r * 2 * NB + ci * NB
                            ..r * 2 * NB + ci * NB + NB]
                            .copy_from_slice(&t[r * NB..(r + 1) * NB]);
                    }
                }
                let mut cbuf = vec![0.0f64; 2 * NB * 2 * NB];
                for ri in 0..bi_n {
                    for ci in 0..bj_n {
                        let t = tile(&a, si + ri, sj + ci);
                        for r in 0..NB {
                            cbuf[(ri * NB + r) * 2 * NB + ci * NB
                                ..(ri * NB + r) * 2 * NB + ci * NB + NB]
                                .copy_from_slice(&t[r * NB..(r + 1) * NB]);
                        }
                    }
                }
                let out =
                    rt.call_f64("hpl_update", &[&abuf, &bbuf, &cbuf])?
                        .remove(0);
                for ri in 0..bi_n {
                    for ci in 0..bj_n {
                        let mut t = vec![0.0; NB * NB];
                        for r in 0..NB {
                            t[r * NB..(r + 1) * NB].copy_from_slice(
                                &out[(ri * NB + r) * 2 * NB + ci * NB
                                    ..(ri * NB + r) * 2 * NB + ci * NB + NB],
                            );
                        }
                        store(&mut a, si + ri, sj + ci, &t);
                        w.compute(
                            owner(si + ri, sj + ci),
                            rt.flops("hpl_update")
                                / NodeRoofline::new(&machine.cfg).gemm_rate(),
                        );
                    }
                }
                sj += 2;
            }
            si += 2;
        }
        coll::barrier(&mut w, &comm);
    }

    // triangular solves on the assembled LU (driver-side; the distributed
    // phase above is what HPL times)
    let mut y = b.clone();
    for i in 0..N {
        for j in 0..i {
            y[i] -= a[i * N + j] * y[j];
        }
    }
    let mut x = y.clone();
    for i in (0..N).rev() {
        for j in i + 1..N {
            x[i] -= a[i * N + j] * x[j];
        }
        x[i] /= a[i * N + i];
    }
    let resid = rt.call_f64("hpl_residual", &[&a0, &x, &b])?[0][0];
    Ok((resid, w.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_for_9234_nodes_matches_paper_shape() {
        // paper: P=162, Q=342 for 9,234 nodes (55,404 ranks)
        let (p, q) = process_grid(9234);
        assert_eq!(p * q, 9234 * 6);
        assert_eq!((p, q), (162, 342));
    }

    #[test]
    fn problem_size_fills_hbm() {
        let cfg = AuroraConfig::aurora();
        let n = problem_size(&cfg, 9234, 0.78);
        let bytes_per_node = (n as f64).powi(2) * 8.0 / 9234.0;
        assert!(bytes_per_node < 896e9, "must fit in HBM");
        assert!(bytes_per_node > 0.6 * 896e9, "should use most of HBM");
    }

    #[test]
    fn headline_efficiency_band() {
        // Table 2: 78.84% at 9,234 nodes => 1.012 EF/s
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 9234);
        assert!(
            (run.efficiency - 0.7884).abs() < 0.03,
            "efficiency {:.4}",
            run.efficiency
        );
        let ef = run.rate / 1e18;
        assert!((ef - 1.012).abs() < 0.05, "rate {ef} EF/s");
    }

    #[test]
    fn efficiency_stable_across_table2_rows() {
        // Table 2: efficiencies 77.3% - 80.5% across 5,439..9,234 nodes
        let cfg = AuroraConfig::aurora();
        for run in table2(&cfg) {
            assert!(
                (0.74..0.84).contains(&run.efficiency),
                "{} nodes: {:.4}",
                run.nodes,
                run.efficiency
            );
        }
    }

    #[test]
    fn curve_is_smooth_with_tail_dip() {
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 5439);
        assert!(run.curve.len() > 50);
        // mid-run rate close to sustained rate (Fig 15 smoothness)
        let mid = run.curve[run.curve.len() / 2].rate;
        assert!((mid / run.rate - 1.0).abs() < 0.35, "mid {mid} vs {}",
            run.rate);
    }

    #[test]
    fn runtime_hours_scale() {
        // paper: 4h 21m 54s at 9,234 nodes
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 9234);
        let hours = run.time / 3600.0;
        assert!((2.0..8.0).contains(&hours), "runtime {hours} h");
    }
}
