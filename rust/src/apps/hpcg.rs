//! HPCG (paper §5.2.4): 5.613 PF/s on 4,096 nodes (~39% of the system).
//!
//! * [`performance`] — HPCG is memory-bandwidth-bound: the model charges
//!   every CG iteration its SpMV/SymGS/vector HBM traffic plus halo
//!   exchanges and the two dot-product allreduces.
//! * [`functional`] — a real preconditioned CG on 8 ranks x 32^3 local
//!   blocks with all local compute through the PJRT artifacts
//!   (`hpcg_spmv`, `hpcg_symgs`, `hpcg_dot`) and halo/allreduce through
//!   the simulated world; validated by residual descent.

use crate::config::AuroraConfig;
use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};
use crate::runtime::Runtime;
use anyhow::Result;

/// HBM bytes moved per HPCG flop (matrix + vectors + index traffic; the
/// reference implementation sits near 10 B/flop).
pub const BYTES_PER_FLOP: f64 = 10.4;
/// Achievable fraction of peak HBM bandwidth for HPCG's access pattern.
pub const MEM_EFF: f64 = 0.78;

#[derive(Debug, Clone)]
pub struct HpcgRun {
    pub nodes: usize,
    pub pflops: f64,
    pub per_node_gflops: f64,
}

pub fn performance(cfg: &AuroraConfig, nodes: usize) -> HpcgRun {
    // per-iteration flops for the local problem: dominated by SymGS (x2)
    // and SpMV; node rate = effective HBM bandwidth / bytes-per-flop
    let node_rate = cfg.gpu_hbm_bw_node * MEM_EFF / BYTES_PER_FLOP;
    // communication overheads: halo faces (~1% of traffic) + 2 allreduce
    // latencies per iteration amortized over the iteration's work
    // 27-pt over ~48M local rows x (SpMV + SymGS x2 + MG coarse levels)
    let iter_flops_node = 2.05e10;
    let t_compute = iter_flops_node / node_rate;
    let t_allreduce = 2.0 * (12.0e-6 * (nodes as f64).log2().max(1.0));
    let t_halo = 0.06 * t_compute;
    let rate = nodes as f64 * iter_flops_node
        / (t_compute + t_halo + t_allreduce);
    HpcgRun {
        nodes,
        pflops: rate / 1e15,
        per_node_gflops: rate / nodes as f64 / 1e9,
    }
}

// ---------------------------------------------------------------- functional

const NL: usize = 32; // local block edge (matches the AOT artifact shapes)

/// State per rank: x, r, p, z over the local 32^3 block.
struct RankState {
    x: Vec<f64>,
    r: Vec<f64>,
    p: Vec<f64>,
}

/// Pad a 2x2x2-rank global field and apply the stencil artifact per rank.
/// Ranks are arranged in a 2x2x2 grid; ghost faces come from neighbours.
fn spmv_all(rt: &mut Runtime, w: &mut World, comm: &Comm,
            fields: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
    let ranks = fields.len();
    let rdim = 2usize;
    let idx3 = |r: usize| (r / 4, (r / 2) % 2, r % 2);
    let g = NL + 2;
    let mut outs = Vec::with_capacity(ranks);
    let mut halo_msgs = Vec::new();
    for rk in 0..ranks {
        let (rz, ry, rx) = idx3(rk);
        let mut padded = vec![0.0f64; g * g * g];
        // interior
        for z in 0..NL {
            for y in 0..NL {
                for x in 0..NL {
                    padded[((z + 1) * g + y + 1) * g + x + 1] =
                        fields[rk][(z * NL + y) * NL + x];
                }
            }
        }
        // ghost faces from neighbours (6 directions inside the 2^3 grid)
        let mut fill = |dz: i32, dy: i32, dx: i32| {
            let nz = rz as i32 + dz;
            let ny = ry as i32 + dy;
            let nx = rx as i32 + dx;
            if !(0..rdim as i32).contains(&nz)
                || !(0..rdim as i32).contains(&ny)
                || !(0..rdim as i32).contains(&nx) {
                return;
            }
            let nb = (nz as usize) * 4 + (ny as usize) * 2 + nx as usize;
            halo_msgs.push((nb, rk, (NL * NL * 8) as u64));
            for a in 0..NL {
                for b in 0..NL {
                    // source plane on the neighbour, dest ghost plane here
                    let (pz, py, px, sz, sy, sx) = match (dz, dy, dx) {
                        (-1, 0, 0) => (0, a + 1, b + 1, NL - 1, a, b),
                        (1, 0, 0) => (g - 1, a + 1, b + 1, 0, a, b),
                        (0, -1, 0) => (a + 1, 0, b + 1, a, NL - 1, b),
                        (0, 1, 0) => (a + 1, g - 1, b + 1, a, 0, b),
                        (0, 0, -1) => (a + 1, b + 1, 0, a, b, NL - 1),
                        _ => (a + 1, b + 1, g - 1, a, b, 0),
                    };
                    padded[(pz * g + py) * g + px] =
                        fields[nb][(sz * NL + sy) * NL + sx];
                }
            }
        };
        for d in [(-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1),
                  (0, 0, 1)] {
            fill(d.0, d.1, d.2);
        }
        let out = rt.call_f32("hpcg_spmv", &[&padded])?.remove(0);
        outs.push(out);
    }
    w.exchange(&halo_msgs);
    let _ = comm;
    Ok(outs)
}

fn dot_all(rt: &mut Runtime, w: &mut World, comm: &Comm, a: &[Vec<f64>],
           b: &[Vec<f64>]) -> Result<f64> {
    let mut local = Vec::new();
    for (x, y) in a.iter().zip(b) {
        local.push(rt.call_f32("hpcg_dot", &[x, y])?[0][0]);
    }
    coll::allreduce(w, comm, 8);
    Ok(local.iter().sum())
}

/// Functional CG (unpreconditioned; SymGS is exercised separately) on
/// 8 ranks. Returns (initial residual, final residual, iterations, time).
pub fn functional(rt: &mut Runtime, machine: &Machine, iters: usize)
    -> Result<(f64, f64, usize, f64)> {
    let ranks = 8;
    let mut w = World::new(&machine.topo, machine.place_job(0, 8, 1));
    let comm = Comm::world(ranks);
    let nloc = NL * NL * NL;
    let mut rng = crate::util::Pcg::new(3);
    // b random, x = 0
    let bvec: Vec<Vec<f64>> = (0..ranks)
        .map(|_| (0..nloc).map(|_| rng.gen_f64() - 0.5).collect())
        .collect();
    let mut st: Vec<RankState> = bvec
        .iter()
        .map(|b| RankState {
            x: vec![0.0; nloc],
            r: b.clone(),
            p: b.clone(),
        })
        .collect();
    let r0 = {
        let r: Vec<Vec<f64>> = st.iter().map(|s| s.r.clone()).collect();
        dot_all(rt, &mut w, &comm, &r, &r)?.sqrt()
    };
    let mut rr_old = r0 * r0;
    let mut done = 0;
    for _ in 0..iters {
        let pfields: Vec<Vec<f64>> = st.iter().map(|s| s.p.clone()).collect();
        let ap = spmv_all(rt, &mut w, &comm, &pfields)?;
        let pap = dot_all(rt, &mut w, &comm, &pfields, &ap)?;
        if pap.abs() < 1e-30 {
            break;
        }
        let alpha = rr_old / pap;
        for (s, apk) in st.iter_mut().zip(&ap) {
            for i in 0..nloc {
                s.x[i] += alpha * s.p[i];
                s.r[i] -= alpha * apk[i];
            }
        }
        let r: Vec<Vec<f64>> = st.iter().map(|s| s.r.clone()).collect();
        let rr_new = dot_all(rt, &mut w, &comm, &r, &r)?;
        let beta = rr_new / rr_old;
        for s in st.iter_mut() {
            for i in 0..nloc {
                s.p[i] = s.r[i] + beta * s.p[i];
            }
        }
        rr_old = rr_new;
        done += 1;
    }
    Ok((r0, rr_old.sqrt(), done, w.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rate_at_4096_nodes() {
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 4096);
        assert!(
            (run.pflops - 5.613).abs() / 5.613 < 0.10,
            "{} PF/s",
            run.pflops
        );
    }

    #[test]
    fn hpcg_is_tiny_fraction_of_hpl() {
        // memory-bound: ~1% of FP64 peak (the HPL/HPCG gap)
        let cfg = AuroraConfig::aurora();
        let run = performance(&cfg, 4096);
        let frac = run.per_node_gflops * 1e9 / cfg.node_fp64_peak;
        assert!(frac < 0.02, "fraction {frac}");
    }

    #[test]
    fn scales_nearly_linearly() {
        let cfg = AuroraConfig::aurora();
        let a = performance(&cfg, 512);
        let b = performance(&cfg, 4096);
        let eff = (b.pflops / a.pflops) / 8.0;
        assert!(eff > 0.9, "weak scaling eff {eff}");
    }
}
