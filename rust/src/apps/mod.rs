//! Benchmarks and applications of paper §5, as workloads over the
//! simulated machine.
//!
//! | module | paper result |
//! |---|---|
//! | [`alltoall`] | Fig 4 (fabric-validation all2all, 228.92 TB/s peak) |
//! | [`osu`] | Fig 6, 7, 10, 11, 12, 13 (OSU/ALCF microbenchmarks) |
//! | [`gpcnet`] | Fig 5 (congestion impact factors) |
//! | [`allreduce`] | Fig 14 (MPI_Allreduce latency, ring<->tree switch) |
//! | [`hpl`] | Fig 15 + Table 2 (1.012 EF/s @ 9,234 nodes) |
//! | [`hpl_mxp`] | Fig 16 (11.64 EF/s @ 9,500 nodes) |
//! | [`graph500`] | §5.2.3 (69,373 GTEPS @ scale 42, 8,192 nodes) |
//! | [`hpcg`] | §5.2.4 (5.613 PF/s @ 4,096 nodes) |
//! | [`hacc`] | Fig 17 + Table 3 (weak scaling, 97% @ 8,192 nodes) |
//! | [`nekbone`] | Fig 18 (>95% @ 4,096 nodes) |
//! | [`amr_wind`] | Fig 19 (FOM weak scaling to 8,192 nodes) |
//! | [`lammps`] | Fig 20 (>85% @ 9,216 nodes, 254B atoms) |
//! | [`fmm`] | Tables 4-6 (one-sided Get/Put, HMEM) |
//!
//! Every module has a performance-mode entry (scales to the full machine
//! via the analytic/round tiers + roofline compute) and, where numerics
//! are checkable, a functional-mode entry that executes the PJRT
//! artifacts over the simulated MPI world.

pub mod allreduce;
pub mod alltoall;
pub mod amr_wind;
pub mod fmm;
pub mod gpcnet;
pub mod graph500;
pub mod hacc;
pub mod hpcg;
pub mod hpl;
pub mod hpl_mxp;
pub mod lammps;
pub mod nekbone;
pub mod osu;

/// One periodic neighbour-halo round over `ranks` world-rank indices —
/// the rank-keyed analogue of `fabric::workload::neighbor_round`, shared
/// by the `step_world` superstep drivers. Offsets that alias to the same
/// partner at small rank counts (e.g. -1/+1 with 2 ranks) are emitted
/// once per (src, dst) pair.
pub(crate) fn rank_halo_round(
    ranks: usize,
    offsets: &[i64],
    bytes: u64,
) -> Vec<(usize, usize, u64)> {
    let mut msgs = Vec::new();
    for i in 0..ranks {
        let mut seen: Vec<usize> = Vec::with_capacity(offsets.len());
        for &off in offsets {
            let j = (i as i64 + off).rem_euclid(ranks as i64) as usize;
            if j != i && !seen.contains(&j) {
                seen.push(j);
                msgs.push((i, j, bytes));
            }
        }
    }
    msgs
}

/// One pairwise-exchange rotation round (`shift` in `1..ranks`) over
/// world-rank indices — the rank-keyed analogue of
/// `fabric::workload::pairwise_rounds`, shared by the `step_world`
/// superstep drivers.
pub(crate) fn rank_pairwise_round(
    ranks: usize,
    shift: usize,
    bytes: u64,
) -> Vec<(usize, usize, u64)> {
    (0..ranks).map(|i| (i, (i + shift) % ranks, bytes)).collect()
}

/// A weak-scaling measurement row shared by the application benches.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub nodes: usize,
    /// Figure of merit (app-specific: time, PFLOP/s, B-cells/s ...).
    pub fom: f64,
    /// Parallel efficiency vs the smallest-node baseline (1.0 = perfect).
    pub efficiency: f64,
}

/// Compute weak-scaling efficiencies from (nodes, time) pairs where ideal
/// weak scaling keeps time constant.
pub fn weak_efficiency_from_times(points: &[(usize, f64)])
    -> Vec<ScalingPoint> {
    let base = points[0].1;
    points
        .iter()
        .map(|&(nodes, t)| ScalingPoint {
            nodes,
            fom: t,
            efficiency: base / t,
        })
        .collect()
}

/// Efficiencies from (nodes, rate) pairs where ideal scaling grows rate
/// linearly with nodes.
pub fn weak_efficiency_from_rates(points: &[(usize, f64)])
    -> Vec<ScalingPoint> {
    let (n0, r0) = points[0];
    points
        .iter()
        .map(|&(nodes, r)| ScalingPoint {
            nodes,
            fom: r,
            efficiency: (r / r0) / (nodes as f64 / n0 as f64),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_from_times() {
        let pts = weak_efficiency_from_times(&[(128, 10.0), (1024, 10.5)]);
        assert!((pts[0].efficiency - 1.0).abs() < 1e-12);
        assert!((pts[1].efficiency - 10.0 / 10.5).abs() < 1e-12);
    }

    #[test]
    fn rank_rounds_shapes_and_alias_dedup() {
        let halo = rank_halo_round(8, &[-1, 1, 2], 64);
        assert_eq!(halo.len(), 24);
        assert!(halo.iter().all(|&(s, d, b)| s != d && b == 64));
        // 2 ranks: -1 and +1 alias to the same partner — emitted once
        let tiny = rank_halo_round(2, &[-1, 1], 8);
        assert_eq!(tiny.len(), 2, "{tiny:?}");
        let pw = rank_pairwise_round(6, 2, 128);
        assert_eq!(pw.len(), 6);
        assert!(pw.iter().all(|&(s, d, _)| d == (s + 2) % 6));
    }

    #[test]
    fn efficiency_from_rates() {
        let pts = weak_efficiency_from_rates(&[(128, 1.0), (1024, 7.6)]);
        assert!((pts[1].efficiency - 0.95).abs() < 1e-9);
    }
}
