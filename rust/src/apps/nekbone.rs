//! Nekbone (paper §5.3.2, Fig 18): spectral-element CG proxy for Nek5000;
//! >95% weak-scaling efficiency to 4,096 nodes at PPN 12, 42,000 elements
//! per rank, polynomial orders nx1 = 9 and 12.
//!
//! Each CG iteration: local Ax (tensor contractions — the `nekbone_ax`
//! artifact), nearest-neighbour halo exchange (gather-scatter), two
//! global allreduces, vector updates.

use crate::config::AuroraConfig;
use crate::machine::Machine;
use crate::mpi::{coll, Comm, World};
use crate::runtime::{Engine, NodeRoofline, Runtime};
use anyhow::Result;

pub use super::ScalingPoint;

pub const PPN: usize = 12;
pub const ELEMS_PER_RANK: usize = 42_000;

/// Flops for one Ax on E elements of order n: 12 n^4 per element
/// (three D-applications + three transposes, 2n flops per output point).
pub fn ax_flops(e: usize, n: usize) -> f64 {
    12.0 * e as f64 * (n as f64).powi(4)
}

/// One CG iteration time at `nodes` for polynomial order `nx1`.
pub fn iter_time(cfg: &AuroraConfig, nodes: usize, nx1: usize) -> f64 {
    let rl = NodeRoofline::new(cfg);
    let e_node = ELEMS_PER_RANK * PPN;
    let f_ax = ax_flops(e_node, nx1);
    let pts_node = e_node as f64 * (nx1 as f64).powi(3);
    // Ax is small-GEMM tensor compute with heavy intermediate traffic
    // (u, 3 directional derivatives, 3 transposes all round-trip HBM)
    let t_ax = rl.node_time(Engine::Fp64, f_ax * 0.35, pts_node * 8.0 * 16.0);
    let t_vec = rl.node_time(Engine::MemoryBound, 0.0, pts_node * 8.0 * 20.0);
    // halo: element faces to ~6 neighbours
    let face_bytes = e_node as f64 * (nx1 as f64).powi(2) * 8.0 * 0.5;
    let t_halo = face_bytes
        / (cfg.nic_eff_bw_host * cfg.nics_per_node as f64)
        + 6.0 * cfg.mpi_overhead;
    // two 8-byte allreduces
    let ranks = (nodes * PPN) as f64;
    let t_allreduce = 2.0 * 10.0e-6 * ranks.log2();
    t_ax + t_vec + t_halo + t_allreduce
}

/// Fig 18: performance (PFLOP/s, averaged over nx1 = 9 and 12) +
/// efficiency across node counts.
pub fn fig18(cfg: &AuroraConfig, node_counts: &[usize]) -> Vec<ScalingPoint> {
    let pts: Vec<(usize, f64)> = node_counts
        .iter()
        .map(|&nodes| {
            let rate: f64 = [9usize, 12]
                .iter()
                .map(|&n| {
                    nodes as f64 * ax_flops(ELEMS_PER_RANK * PPN, n)
                        / iter_time(cfg, nodes, n)
                })
                .sum::<f64>()
                / 2.0;
            (nodes, rate)
        })
        .collect();
    super::weak_efficiency_from_rates(&pts)
}

/// Functional CG on the spectral-element operator: one rank, E=32
/// elements of order 9 through the `nekbone_ax` artifact + simulated
/// allreduces across 4 ranks. Returns (r0, r_final, iterations, time).
pub fn functional(rt: &mut Runtime, machine: &Machine, iters: usize)
    -> Result<(f64, f64, usize, f64)> {
    const E: usize = 32;
    const N: usize = 9;
    let len = E * N * N * N;
    let mut w = World::new(&machine.topo, machine.place_job(0, 4, 1));
    let comm = Comm::world(4);

    // derivative operator: tridiagonal-ish SPD-generating D
    let mut d = vec![0.0f64; N * N];
    for i in 0..N {
        for j in 0..N {
            d[i * N + j] = if i == j {
                2.0
            } else if i.abs_diff(j) == 1 {
                -1.0
            } else {
                0.0
            };
        }
    }
    let ax = |rt: &mut Runtime, u: &[f64]| -> Result<Vec<f64>> {
        let mut out = rt.call_f64("nekbone_ax", &[u, &d])?.remove(0);
        // shift to make strictly positive definite (mass-matrix term)
        for (o, ui) in out.iter_mut().zip(u) {
            *o += 0.5 * ui;
        }
        Ok(out)
    };

    let mut rng = crate::util::Pcg::new(17);
    let b: Vec<f64> = (0..len).map(|_| rng.gen_f64() - 0.5).collect();
    let mut x = vec![0.0f64; len];
    let mut r = b.clone();
    let mut p = r.clone();
    let dot = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    };
    let r0 = dot(&r, &r).sqrt();
    let mut rr = r0 * r0;
    let mut done = 0;
    for _ in 0..iters {
        let apv = ax(rt, &p)?;
        let pap = dot(&p, &apv);
        if pap.abs() < 1e-30 {
            break;
        }
        let alpha = rr / pap;
        for i in 0..len {
            x[i] += alpha * p[i];
            r[i] -= alpha * apv[i];
        }
        let rr_new = dot(&r, &r);
        coll::allreduce(&mut w, &comm, 8);
        coll::allreduce(&mut w, &comm, 8);
        let beta = rr_new / rr;
        for i in 0..len {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr_new;
        done += 1;
    }
    Ok((r0, rr.sqrt(), done, w.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weak_scaling_over_95_percent() {
        // Fig 18: >95% parallel efficiency up to 4,096 nodes
        let cfg = AuroraConfig::aurora();
        let pts = fig18(&cfg, &[128, 512, 2048, 4096]);
        for p in &pts {
            assert!(
                p.efficiency > 0.95,
                "{} nodes: eff {}",
                p.nodes,
                p.efficiency
            );
        }
    }

    #[test]
    fn higher_order_is_more_efficient() {
        // nx1=12 has better flop/byte => higher rate per node
        let cfg = AuroraConfig::aurora();
        let r9 = ax_flops(ELEMS_PER_RANK * PPN, 9)
            / iter_time(&cfg, 1024, 9);
        let r12 = ax_flops(ELEMS_PER_RANK * PPN, 12)
            / iter_time(&cfg, 1024, 12);
        assert!(r12 > r9, "r9 {r9} r12 {r12}");
    }

    #[test]
    fn rate_is_petascale_at_4096() {
        // Fig 18 reports PFLOP/s-scale aggregate performance
        let cfg = AuroraConfig::aurora();
        let pts = fig18(&cfg, &[4096]);
        let pf = pts[0].fom / 1e15;
        assert!(pf > 1.0 && pf < 60.0, "{pf} PF/s");
    }
}
