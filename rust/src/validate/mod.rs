//! Fabric validation methodology (paper §3.8): the systematic pipeline
//! that gated every large run on Aurora.
//!
//! * levels: node loopback -> switch -> group -> system (§3.8.5);
//! * pre-flight all2all before HPL/HPL-MxP (§3.8.1), GPCNet gate (§3.8.2);
//! * prolog tests (cxi_healthcheck, cxi_gpu_loopback, slingshot-diag) and
//!   epilog tests (flap offlining, service cleanup, error thresholds)
//!   (§3.8.9);
//! * low-performing-node identification -> corrective action ->
//!   revalidation -> return to pool (§3.8.7).
//!
//! Faults are injected per node (performance factor, hardware-error
//! counts, flap counts) so the pipeline's isolation logic is testable.

use crate::machine::Machine;
use std::collections::{HashMap, HashSet};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    Loopback,
    Switch,
    Group,
    System,
}

/// Injected node condition (what §3.8.7 calls node-level issues).
#[derive(Debug, Clone, Copy)]
pub struct NodeFault {
    /// Multiplier on NIC throughput (PCIe/memory/CPU issues).
    pub perf_factor: f64,
    /// Logged hardware errors (PCIe, memory, CPU, NIC).
    pub hw_errors: u32,
    /// CASSINI edge-link flaps during the job.
    pub flaps: u32,
}

impl Default for NodeFault {
    fn default() -> Self {
        Self { perf_factor: 1.0, hw_errors: 0, flaps: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub level: Level,
    pub tested_nodes: usize,
    pub failed_nodes: Vec<usize>,
    /// Aggregate bandwidth observed (bytes/s) for bandwidth levels.
    pub aggregate_bw: f64,
}

/// Node lifecycle in the validation pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodePool {
    Available,
    Offlined,
    UnderRepair,
}

pub struct Validator<'m> {
    pub machine: &'m Machine,
    pub faults: HashMap<usize, NodeFault>,
    pub pool: HashMap<usize, NodePool>,
    /// Minimum acceptable fraction of expected per-node bandwidth.
    pub perf_threshold: f64,
    /// Epilog threshold: hw errors beyond this offline the node.
    pub hw_error_threshold: u32,
}

impl<'m> Validator<'m> {
    pub fn new(machine: &'m Machine) -> Self {
        Self {
            machine,
            faults: HashMap::new(),
            pool: HashMap::new(),
            perf_threshold: 0.85,
            hw_error_threshold: 10,
        }
    }

    pub fn inject(&mut self, node: usize, fault: NodeFault) {
        self.faults.insert(node, fault);
    }

    fn fault(&self, node: usize) -> NodeFault {
        self.faults.get(&node).copied().unwrap_or_default()
    }

    fn pool_state(&self, node: usize) -> NodePool {
        self.pool.get(&node).copied().unwrap_or(NodePool::Available)
    }

    /// Measured loopback throughput of one node (cxi_gpu_loopback): the
    /// NIC effective bandwidth scaled by any injected node fault.
    pub fn loopback_bw(&self, node: usize) -> f64 {
        self.machine.cfg.nic_eff_bw_host * self.fault(node).perf_factor
    }

    // ------------------------------------------------ §3.8.9 prolog

    /// cxi_healthcheck: device-level gate.
    pub fn cxi_healthcheck(&self, node: usize) -> bool {
        let f = self.fault(node);
        f.hw_errors == 0 && f.perf_factor > 0.5
    }

    /// slingshot-diag: additional software/hardware diagnostics.
    pub fn slingshot_diag(&self, node: usize) -> bool {
        self.fault(node).flaps == 0
    }

    /// Full prolog for a candidate node set; returns nodes that may run.
    pub fn prolog(&self, nodes: &[usize]) -> Vec<usize> {
        nodes
            .iter()
            .copied()
            .filter(|&n| {
                self.pool_state(n) == NodePool::Available
                    && self.cxi_healthcheck(n)
                    && self.slingshot_diag(n)
                    && self.loopback_bw(n)
                        >= self.perf_threshold
                            * self.machine.cfg.nic_eff_bw_host
            })
            .collect()
    }

    // ------------------------------------------------ §3.8.9 epilog

    /// Epilog: offline nodes with flaps or hardware errors past threshold.
    /// Returns the offlined nodes.
    pub fn epilog(&mut self, nodes: &[usize]) -> Vec<usize> {
        let mut offlined = Vec::new();
        for &n in nodes {
            let f = self.fault(n);
            if f.flaps > 0 || f.hw_errors > self.hw_error_threshold {
                self.pool.insert(n, NodePool::Offlined);
                offlined.push(n);
            }
        }
        offlined
    }

    // ------------------------------------------------ §3.8.5 levels

    /// Run one validation level over `nodes`. Bandwidth-bearing levels
    /// compare each node's effective throughput against the healthy
    /// expectation and flag under-performers (§3.8.7).
    pub fn validate(&self, level: Level, nodes: &[usize]) -> ValidationReport {
        let expect = self.machine.cfg.nic_eff_bw_host;
        let mut failed = Vec::new();
        let mut agg = 0.0;
        for &n in nodes {
            if self.pool_state(n) != NodePool::Available {
                failed.push(n);
                continue;
            }
            let bw = match level {
                Level::Loopback => self.loopback_bw(n),
                // switch/group/system levels exercise progressively longer
                // paths; a healthy fabric keeps per-node bw flat, node
                // faults show up at every level
                Level::Switch | Level::Group | Level::System => {
                    self.loopback_bw(n)
                }
            };
            if bw < self.perf_threshold * expect || !self.cxi_healthcheck(n) {
                failed.push(n);
            } else {
                agg += bw * self.machine.cfg.nics_per_node as f64;
            }
        }
        ValidationReport {
            level,
            tested_nodes: nodes.len(),
            failed_nodes: failed,
            aggregate_bw: agg,
        }
    }

    /// The systematic §3.8.5 ladder: loopback -> switch -> group ->
    /// system. A node must pass every level; failures are isolated at the
    /// earliest level (the paper's "overall system health depends on the
    /// health of all groups" principle).
    pub fn systematic(&mut self, nodes: &[usize]) -> Vec<ValidationReport> {
        let mut remaining: Vec<usize> = nodes.to_vec();
        let mut reports = Vec::new();
        for level in [Level::Loopback, Level::Switch, Level::Group,
                      Level::System] {
            let rep = self.validate(level, &remaining);
            let failed: HashSet<usize> =
                rep.failed_nodes.iter().copied().collect();
            for &n in &failed {
                self.pool.insert(n, NodePool::Offlined);
            }
            remaining.retain(|n| !failed.contains(n));
            reports.push(rep);
        }
        reports
    }

    /// §3.8.7 repair loop: offlined nodes get corrective hardware action
    /// (fault cleared), are revalidated, and return to the pool.
    pub fn repair_and_revalidate(&mut self) -> Vec<usize> {
        let offlined: Vec<usize> = self
            .pool
            .iter()
            .filter(|(_, s)| **s == NodePool::Offlined)
            .map(|(n, _)| *n)
            .collect();
        let mut restored = Vec::new();
        for n in offlined {
            self.pool.insert(n, NodePool::UnderRepair);
            // corrective hardware action
            self.faults.remove(&n);
            // revalidation: tentatively return to pool, re-offline on fail
            self.pool.insert(n, NodePool::Available);
            let rep = self.validate(Level::Loopback, &[n]);
            if rep.failed_nodes.is_empty() {
                restored.push(n);
            } else {
                self.pool.insert(n, NodePool::Offlined);
            }
        }
        restored
    }

    /// Pre-flight gate for a large run (§3.8.1): systematic validation,
    /// then return the healthy node set (what HPL/HPL-MxP actually used —
    /// 9,234 of 10,624 nodes etc.).
    pub fn preflight(&mut self, want: usize) -> Vec<usize> {
        let all: Vec<usize> = (0..self.machine.cfg.nodes()).collect();
        self.systematic(&all);
        all.into_iter()
            .filter(|&n| self.pool_state(n) == NodePool::Available)
            .take(want)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    fn machine() -> Machine {
        Machine::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn healthy_nodes_pass_all_levels() {
        let m = machine();
        let mut v = Validator::new(&m);
        let nodes: Vec<usize> = (0..m.cfg.nodes()).collect();
        let reports = v.systematic(&nodes);
        assert_eq!(reports.len(), 4);
        assert!(reports.iter().all(|r| r.failed_nodes.is_empty()));
    }

    #[test]
    fn slow_node_isolated_at_loopback() {
        let m = machine();
        let mut v = Validator::new(&m);
        v.inject(3, NodeFault { perf_factor: 0.5, ..Default::default() });
        let nodes: Vec<usize> = (0..8).collect();
        let reports = v.systematic(&nodes);
        assert_eq!(reports[0].failed_nodes, vec![3]);
        // later levels never see node 3 again
        assert_eq!(reports[1].tested_nodes, 7);
    }

    #[test]
    fn prolog_filters_unhealthy() {
        let m = machine();
        let mut v = Validator::new(&m);
        v.inject(1, NodeFault { hw_errors: 2, ..Default::default() });
        v.inject(2, NodeFault { flaps: 1, ..Default::default() });
        let ok = v.prolog(&[0, 1, 2, 3]);
        assert_eq!(ok, vec![0, 3]);
    }

    #[test]
    fn epilog_offlines_flapping_nodes() {
        let m = machine();
        let mut v = Validator::new(&m);
        v.inject(5, NodeFault { flaps: 2, ..Default::default() });
        let off = v.epilog(&[4, 5, 6]);
        assert_eq!(off, vec![5]);
        assert_eq!(v.pool[&5], NodePool::Offlined);
    }

    #[test]
    fn repair_loop_restores_nodes() {
        let m = machine();
        let mut v = Validator::new(&m);
        v.inject(2, NodeFault { perf_factor: 0.3, ..Default::default() });
        v.systematic(&(0..8).collect::<Vec<_>>());
        assert_eq!(v.pool[&2], NodePool::Offlined);
        let restored = v.repair_and_revalidate();
        assert_eq!(restored, vec![2]);
        // node is usable again
        assert!(v.prolog(&[2]).contains(&2));
    }

    #[test]
    fn preflight_returns_requested_healthy_subset() {
        let m = machine();
        let mut v = Validator::new(&m);
        v.inject(0, NodeFault { perf_factor: 0.1, ..Default::default() });
        let got = v.preflight(10);
        assert_eq!(got.len(), 10);
        assert!(!got.contains(&0), "faulty node excluded");
    }
}
