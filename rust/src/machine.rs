//! The assembled machine: topology + node models + global NIC numbering,
//! plus the Table 1 aggregate-specification report.

use crate::config::AuroraConfig;
use crate::node::{place_ranks, RankLoc};
use crate::topology::Topology;

/// A fully described machine instance. Cheap to clone conceptually but we
/// pass references; topology is computed algorithmically so memory is O(1)
/// in machine size.
#[derive(Debug, Clone)]
pub struct Machine {
    pub cfg: AuroraConfig,
    pub topo: Topology,
}

impl Machine {
    pub fn new(cfg: &AuroraConfig) -> Self {
        Self { cfg: cfg.clone(), topo: Topology::new(cfg) }
    }

    pub fn aurora() -> Self {
        Self::new(&AuroraConfig::aurora())
    }

    /// Global NIC id for a rank placement.
    pub fn nic_of(&self, loc: &RankLoc) -> u32 {
        self.topo.nic_of_node(loc.node, loc.nic_idx)
    }

    /// Place a job: `nodes` consecutive node ids starting at `first_node`,
    /// `ppn` ranks per node with the §3.8.4 balanced binding.
    pub fn place_job(&self, first_node: usize, nodes: usize, ppn: usize)
        -> Vec<RankLoc> {
        assert!(
            first_node + nodes <= self.cfg.nodes(),
            "job of {nodes} nodes at {first_node} exceeds machine ({})",
            self.cfg.nodes()
        );
        let ids: Vec<usize> = (first_node..first_node + nodes).collect();
        place_ranks(&self.cfg, &ids, ppn)
    }

    /// Paper Table 1, regenerated from the model.
    pub fn spec_table(&self) -> String {
        let c = &self.cfg;
        let nodes = c.nodes();
        let cpus = nodes * c.sockets_per_node;
        let gpus = nodes * c.gpus_per_node;
        let ddr_pb = nodes as f64 * c.ddr_per_node_gb / 1e6;
        let hbm_pb = nodes as f64 * c.hbm_per_node_gb / 1e6;
        // DDR5-4800 x 8 channels x 2 sockets = 0.5 TB/s/node
        let ddr_bw_pbs = nodes as f64 * 0.5e12 / 1e15;
        // 2 x 1.64 (CPU HBM2e) + 6 x 3.28 (PVC) ~ 13.9 TB/s/node
        let hbm_bw_pbs = nodes as f64 * 13.88e12 / 1e15;
        format!(
            "Table 1: Aurora Aggregate Specifications (model-derived)\n\
             | Nodes                  | {nodes} |\n\
             | No. of CPUs            | {cpus} |\n\
             | No. of GPUs            | {gpus} |\n\
             | DDR5 Memory Capacity   | {ddr_pb:.2} PB |\n\
             | DDR5 Memory Bandwidth  | {ddr_bw_pbs:.2} PB/s |\n\
             | HBM2e Memory Capacity  | {hbm_pb:.2} PB |\n\
             | HBM2e Memory Bandwidth | {hbm_bw_pbs:.2} PB/s |\n\
             | Injection Bandwidth    | {:.2} PB/s |\n\
             | Global Bandwidth       | {:.2} PB/s |",
            c.injection_bw() / 1e15,
            c.global_bw() / 1e15,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_table_matches_paper_table1() {
        let m = Machine::aurora();
        let t = m.spec_table();
        assert!(t.contains("| Nodes                  | 10624 |"), "{t}");
        assert!(t.contains("| No. of CPUs            | 21248 |"), "{t}");
        assert!(t.contains("| No. of GPUs            | 63744 |"), "{t}");
        assert!(t.contains("| Injection Bandwidth    | 2.12 PB/s |"), "{t}");
        assert!(t.contains("| Global Bandwidth       | 1.37 PB/s |"), "{t}");
        // paper Table 1 prints 10.62 PB (1 TB/node, decimal); the §2 node
        // description (2 x 512 GB) gives 10.88 PB — we follow §2.
        assert!(t.contains("| DDR5 Memory Capacity   | 10.88 PB |"), "{t}");
        assert!(t.contains("| HBM2e Memory Capacity  | 9.52 PB |"), "{t}");
    }

    #[test]
    fn job_placement_bounds_checked() {
        let m = Machine::new(&AuroraConfig::tiny());
        let locs = m.place_job(0, 4, 8);
        assert_eq!(locs.len(), 32);
        let nics: std::collections::HashSet<u32> =
            locs.iter().map(|l| m.nic_of(l)).collect();
        assert_eq!(nics.len(), 32, "each rank gets its own NIC at ppn 8");
    }

    #[test]
    #[should_panic(expected = "exceeds machine")]
    fn oversubscribed_job_panics() {
        let m = Machine::new(&AuroraConfig::tiny());
        m.place_job(0, 100, 8);
    }
}
