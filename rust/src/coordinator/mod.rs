//! Job coordinator: the `mpiexec`-like launcher that ties the stack
//! together (paper §3.8.4, §3.8.9).
//!
//! A [`JobSpec`] describes nodes/PPN/bindings; [`Launcher::launch`] runs
//! the §3.8.9 prolog gate (cxi_healthcheck, gpu loopback, slingshot-diag),
//! places ranks with the §3.8.4 NUMA-balanced binding, builds the MPI
//! [`World`], hands it to the application closure, then runs the epilog
//! (flap offlining, error thresholds) and emits the MPICH network summary
//! plus the CXI counter report (§3.8.6-§3.8.8).

use crate::campaign::{Campaign, CampaignReport};
use crate::fabric::BufLoc;
use crate::machine::Machine;
use crate::mpi::World;
use crate::node::NumaMap;
use crate::validate::Validator;
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub nodes: usize,
    pub ppn: usize,
    /// Place message buffers in GPU memory (GPU-direct path).
    pub gpu_buffers: bool,
    /// Emit the verbose CXI counter report (MPICH_OFI_CXI_COUNTER_VERBOSE).
    pub counter_verbose: bool,
}

impl JobSpec {
    pub fn new(name: &str, nodes: usize, ppn: usize) -> Self {
        Self {
            name: name.to_string(),
            nodes,
            ppn,
            gpu_buffers: false,
            counter_verbose: false,
        }
    }

    pub fn ranks(&self) -> usize {
        self.nodes * self.ppn
    }
}

#[derive(Debug, Clone)]
pub struct JobReport<T> {
    pub spec_name: String,
    pub result: T,
    /// Simulated wall time of the job.
    pub elapsed: f64,
    /// Nodes that failed prolog and were replaced.
    pub replaced_nodes: Vec<usize>,
    /// Nodes offlined by the epilog.
    pub offlined_nodes: Vec<usize>,
    pub mpich_summary: String,
    pub counter_report: String,
    /// The cpu-bind list used (per §3.8.4).
    pub cpu_binds: Vec<String>,
}

pub struct Launcher<'m> {
    pub machine: &'m Machine,
    pub validator: Validator<'m>,
}

impl<'m> Launcher<'m> {
    pub fn new(machine: &'m Machine) -> Self {
        Self { machine, validator: Validator::new(machine) }
    }

    /// Launch a job: prolog-gate nodes, build the world, run `app`,
    /// epilog, report.
    pub fn launch<T>(
        &mut self,
        spec: &JobSpec,
        app: impl FnOnce(&mut World) -> T,
    ) -> Result<JobReport<T>> {
        let total = self.machine.cfg.nodes();
        if spec.nodes > total {
            bail!("job wants {} nodes, machine has {total}", spec.nodes);
        }
        // --- prolog: find enough healthy nodes (§3.8.9) ---
        let candidates: Vec<usize> = (0..total).collect();
        let healthy = self.validator.prolog(&candidates);
        if healthy.len() < spec.nodes {
            bail!(
                "only {}/{} nodes pass prolog",
                healthy.len(),
                spec.nodes
            );
        }
        let wanted: Vec<usize> = (0..spec.nodes).collect();
        let replaced: Vec<usize> = wanted
            .iter()
            .copied()
            .filter(|n| !healthy.contains(n))
            .collect();
        let job_nodes: Vec<usize> =
            healthy.into_iter().take(spec.nodes).collect();

        // --- placement + binding ---
        let placements =
            crate::node::place_ranks(&self.machine.cfg, &job_nodes, spec.ppn);
        let cpu_binds =
            NumaMap::new(&self.machine.cfg).cpu_bind_list(spec.ppn);
        let mut world = World::new(&self.machine.topo, placements);
        if spec.gpu_buffers {
            world.buf = BufLoc::Gpu;
        }

        // --- run ---
        let result = app(&mut world);
        let elapsed = world.elapsed();

        // --- epilog (§3.8.9) ---
        let offlined = self.validator.epilog(&job_nodes);

        Ok(JobReport {
            spec_name: spec.name.clone(),
            result,
            elapsed,
            replaced_nodes: replaced,
            offlined_nodes: offlined,
            mpich_summary: world.mpich_summary(),
            counter_report: world.counters.report(spec.counter_verbose),
            cpu_binds,
        })
    }

    /// Launch a scenario campaign through the same operational gates a
    /// job gets: the §3.8.9 prolog must leave enough healthy nodes to
    /// host the sweep before any scenario runs, and the epilog runs after
    /// the campaign completes. Scenarios execute in parallel on up to
    /// `threads` workers (deterministic output; see [`crate::campaign`]).
    /// Returns the report plus the nodes the epilog offlined (the
    /// campaign analogue of [`JobReport::offlined_nodes`]): the
    /// validator's own findings merged with every node a scenario's
    /// fault timeline took down (`NodeDown` is terminal, so a node down
    /// at any point in a priced schedule is down at epilog time) — the
    /// epilog reports what the DES actually priced, not a static list.
    pub fn launch_campaign(
        &mut self,
        campaign: &Campaign,
        threads: usize,
    ) -> Result<(CampaignReport, Vec<usize>)> {
        let total = self.machine.cfg.nodes();
        let candidates: Vec<usize> = (0..total).collect();
        let healthy = self.validator.prolog(&candidates);
        if healthy.len() * 2 < total {
            bail!(
                "campaign aborted: only {}/{total} nodes pass prolog",
                healthy.len()
            );
        }
        let report = campaign.run(threads.max(1));
        let mut offlined = self.validator.epilog(&healthy);
        for s in &campaign.scenarios {
            if let Some(fs) = &s.opts.faults {
                offlined.extend(
                    fs.nodes_down_at(f64::INFINITY)
                        .into_iter()
                        .map(|n| n as usize),
                );
            }
        }
        offlined.sort_unstable();
        offlined.dedup();
        Ok((report, offlined))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::mpi::{coll, Comm};
    use crate::validate::NodeFault;

    fn machine() -> Machine {
        Machine::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn launch_runs_app_and_reports() {
        let m = machine();
        let mut l = Launcher::new(&m);
        let spec = JobSpec::new("allreduce-smoke", 8, 2);
        let rep = l
            .launch(&spec, |w| coll::allreduce(w, &Comm::world(16), 4096))
            .unwrap();
        assert!(rep.result > 0.0);
        assert!(rep.elapsed > 0.0);
        assert!(rep.mpich_summary.contains("network timeouts"));
        assert_eq!(rep.cpu_binds.len(), 2);
        assert!(rep.replaced_nodes.is_empty());
    }

    #[test]
    fn prolog_replaces_faulty_nodes() {
        let m = machine();
        let mut l = Launcher::new(&m);
        l.validator
            .inject(0, NodeFault { perf_factor: 0.2, ..Default::default() });
        let spec = JobSpec::new("x", 4, 1);
        let rep = l.launch(&spec, |w| w.size()).unwrap();
        assert_eq!(rep.result, 4);
        assert_eq!(rep.replaced_nodes, vec![0]);
    }

    #[test]
    fn epilog_runs_clean_on_healthy_job() {
        let m = machine();
        let mut l = Launcher::new(&m);
        let spec = JobSpec::new("x", 2, 1);
        let rep = l.launch(&spec, |_| ()).unwrap();
        assert!(rep.offlined_nodes.is_empty());
    }

    #[test]
    fn oversized_job_rejected() {
        let m = machine();
        let mut l = Launcher::new(&m);
        assert!(l.launch(&JobSpec::new("big", 10_000, 1), |_| ()).is_err());
    }

    #[test]
    fn campaign_launch_gates_and_reports() {
        use crate::campaign::Campaign;
        let m = machine();
        let mut l = Launcher::new(&m);
        let mut c = Campaign::standard(&m.cfg, 11);
        c.scenarios.truncate(3); // keep the unit test quick
        let (rep, offlined) = l.launch_campaign(&c, 2).unwrap();
        assert_eq!(rep.results.len(), 3);
        assert!(rep.results.iter().all(|r| r.makespan > 0.0));
        // a healthy machine offlines nothing
        assert!(offlined.is_empty(), "{offlined:?}");
    }

    #[test]
    fn campaign_epilog_offlines_fault_scheduled_nodes() {
        use crate::campaign::{Campaign, Scenario, Workload};
        use crate::fabric::des::DesOpts;
        use crate::fabric::faults::{FaultKind, FaultPolicy, FaultSchedule};
        let m = machine();
        let mut l = Launcher::new(&m);
        // NodeDown fires long after the ring completes: it must not
        // perturb the result, but the epilog still reports the node
        // because the schedule priced it as terminally down.
        let fs = FaultSchedule::new(FaultPolicy::Reroute)
            .at(1.0, FaultKind::NodeDown { node: 3 });
        let mut c = Campaign::new();
        c.push(Scenario::new(
            "node-down-epilog",
            m.cfg.clone(),
            DesOpts { faults: Some(fs), ..DesOpts::default() },
            Workload::Ring { ranks: 8, bytes: 1 << 20 },
            7,
        ));
        let (rep, offlined) = l.launch_campaign(&c, 1).unwrap();
        assert_eq!(rep.results.len(), 1);
        assert_eq!(offlined, vec![3]);
    }

    #[test]
    fn gpu_buffer_jobs_use_gpu_path() {
        let m = machine();
        let mut l = Launcher::new(&m);
        let mut spec = JobSpec::new("gpu", 2, 1);
        spec.gpu_buffers = true;
        let rep = l
            .launch(&spec, |w| matches!(w.buf, BufLoc::Gpu))
            .unwrap();
        assert!(rep.result);
    }
}
