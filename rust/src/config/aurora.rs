//! Named machine configurations.

use super::{AuroraConfig, GB, NS, US};

impl AuroraConfig {
    /// The full Aurora system as described in paper §2-§3 (Table 1, Fig 2):
    /// 166 compute groups x 32 switches x 2 nodes = 10,624 nodes,
    /// 84,992 compute endpoints.
    pub fn aurora() -> Self {
        Self {
            compute_groups: 166,
            storage_groups: 8,
            service_groups: 1,
            switches_per_group: 32,
            nodes_per_switch: 2,
            nics_per_node: 8,
            global_links_compute: 2,
            global_links_daos: 24,
            global_links_noncompute: 2,

            nic_bw: 25.0 * GB,
            global_link_bw: 25.0 * GB,
            local_link_bw: 25.0 * GB,
            switch_latency: 0.35 * US,
            nic_latency: 0.30 * US,
            mpi_overhead: 0.55 * US,
            electrical_prop: 15.0 * NS,
            optical_prop: 150.0 * NS,
            nic_sram_msg_bytes: 64,
            dram_spill_penalty: 1.1 * US,
            nic_msg_rate: 1.8e8,

            rank_issue_bw_host: 14.0 * GB,
            rank_issue_bw_gpu: 12.5 * GB,
            nic_eff_bw_host: 22.5 * GB,
            nic_eff_bw_gpu: 17.5 * GB, // 70 GB/s socket aggregate over 4 NICs
            xelink_bw: 28.0 * GB,
            pcie5_bw: 64.0 * GB,
            cores_per_socket: 52,
            sockets_per_node: 2,
            gpus_per_node: 6,
            hbm_per_node_gb: 896.0,
            ddr_per_node_gb: 1024.0,
            gpu_hbm_bw_node: 19.66e12,

            node_fp64_peak: 139.0e12,
            node_mxp_peak: 2.40e15,
            gemm_eff: 0.87,
            mxp_gemm_eff: 0.61,

            adaptive_candidates: 4,
            nonminimal_threshold: 1.5,
            nonminimal_bias: 2.0,
            group_load_setting: true,
            congestion_mgmt: true,

            allreduce_tree_cutoff: 64 * 1024,
            eager_threshold: 8 * 1024,

            rma_get_hmem_op: 0.55 * US,
            rma_get_nohmem_op: 128.0 * US,
            rma_put_hmem_op: 8.2 * US,
            rma_put_nohmem_op: 17.9 * US,
            rma_internode_overhead: 60.0 * US,
            rma_buffer_ops: 2000,
            rma_buffer_ops_put_nohmem: 100,
        }
    }

    /// The full-machine preset for DES-tier runs: identical to
    /// [`AuroraConfig::aurora`] (166 compute groups, 10,624 nodes,
    /// 84,992 compute endpoints), named separately because it is now a
    /// *simulatable* scale, not just an analytic anchor — the
    /// component-parallel DES solve plus the dense
    /// [`crate::topology::Topology::link_index`] data layout route and
    /// price multi-group workloads at >= 16,384 endpoints on it
    /// (EXPERIMENTS.md §Full-Aurora preset; gated by the
    /// `des_component_parallel_full_aurora` bench).
    pub fn full_aurora() -> Self {
        Self::aurora()
    }

    /// A scaled-down dragonfly with the same per-link/per-node constants —
    /// used by functional-mode runs and the test suite. `groups` compute
    /// groups of `switches` switches each.
    pub fn small(groups: usize, switches: usize) -> Self {
        Self {
            compute_groups: groups,
            storage_groups: 0,
            service_groups: 0,
            switches_per_group: switches,
            ..Self::aurora()
        }
    }

    /// Minimal 2-group machine (8 nodes) for unit tests.
    pub fn tiny() -> Self {
        Self::small(2, 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aurora_matches_table1() {
        let c = AuroraConfig::aurora();
        // Paper Table 1 and §3.1
        assert_eq!(c.nodes(), 10_624);
        assert_eq!(c.compute_endpoints(), 84_992);
        assert_eq!(c.endpoints_per_group(), 512);
        // 2.12 PB/s injection
        let inj_pb = c.injection_bw() / 1e15;
        assert!((inj_pb - 2.12).abs() < 0.01, "injection {inj_pb} PB/s");
        // 1.37 PB/s global
        let glob_pb = c.global_bw() / 1e15;
        assert!((glob_pb - 1.37).abs() < 0.01, "global {glob_pb} PB/s");
        // 0.69 PB/s bisection
        let bis_pb = c.global_bisection_bw() / 1e15;
        assert!((bis_pb - 0.69).abs() < 0.01, "bisection {bis_pb} PB/s");
    }

    #[test]
    fn full_aurora_is_the_table1_machine_at_des_scale() {
        let c = AuroraConfig::full_aurora();
        assert_eq!(c.nodes(), 10_624);
        assert_eq!(c.compute_endpoints(), 84_992);
        // the full-Aurora DES scenario needs 128 group-aligned blocks
        // of 128 endpoints: 16,384 endpoints, well inside the machine
        assert!(c.compute_groups >= 128);
        assert!(c.endpoints_per_group() >= 128);
    }

    #[test]
    fn tiny_is_consistent() {
        let c = AuroraConfig::tiny();
        assert_eq!(c.nodes(), 8);
        assert_eq!(c.compute_endpoints(), 64);
    }
}
