//! System configuration: the single source of truth for every hardware
//! constant in the simulation.
//!
//! All constants are taken from the paper itself (§2 Table 1, §3.1-3.3,
//! §5.1) or derived from its measured results; each field documents its
//! provenance. `AuroraConfig::aurora()` is the 10,624-node machine;
//! smaller configs scale the dragonfly down for functional runs and tests.

mod aurora;

#[allow(unused_imports)]
pub use aurora::*;


/// Gigabytes per second in bytes/sec.
pub const GB: f64 = 1e9;
/// Microseconds in seconds.
pub const US: f64 = 1e-6;
/// Nanoseconds in seconds.
pub const NS: f64 = 1e-9;

/// Dragonfly + node shape and calibration constants.
#[derive(Debug, Clone)]
pub struct AuroraConfig {
    // ----- dragonfly shape (paper §3.1, Fig 2) -----
    /// Compute groups (Aurora: 166, one HPE Cray EX cabinet each).
    pub compute_groups: usize,
    /// DAOS storage groups (Aurora: 8).
    pub storage_groups: usize,
    /// Service groups (Aurora: 1).
    pub service_groups: usize,
    /// Switches per group, all-to-all connected intra-group (Aurora: 32).
    pub switches_per_group: usize,
    /// Nodes attached to each switch (Aurora: 2).
    pub nodes_per_switch: usize,
    /// NICs (endpoints) per node (Aurora: 8).
    pub nics_per_node: usize,
    /// Global links between each pair of compute groups (Aurora: 2).
    pub global_links_compute: usize,
    /// Global links between each pair of DAOS groups (Aurora: 24).
    pub global_links_daos: usize,
    /// Global links from each compute group to each non-compute group (2).
    pub global_links_noncompute: usize,

    // ----- link & switch timing (paper §3.1-3.4, §5.1 Fig 10) -----
    /// NIC line rate per direction: 200 Gbps = 25 GB/s (§3.3).
    pub nic_bw: f64,
    /// Optical global cable: 50 GB/s/dir carrying 2 links => 25 GB/s/link.
    pub global_link_bw: f64,
    /// Intra-group electrical link bandwidth (same 200 Gbps lanes).
    pub local_link_bw: f64,
    /// Rosetta port-to-port switch latency (850 MHz pipeline).
    pub switch_latency: f64,
    /// NIC send/receive processing per message (Cassini + libfabric).
    pub nic_latency: f64,
    /// MPI software overhead per message (MPICH CH4/OFI path).
    pub mpi_overhead: f64,
    /// Electrical intra-group cable propagation delay.
    pub electrical_prop: f64,
    /// Optical global cable propagation delay (tens of meters).
    pub optical_prop: f64,
    /// Messages <= this stay in Cassini SRAM; larger spill to host DRAM
    /// (the 64 B -> 128 B latency jump of Fig 10).
    pub nic_sram_msg_bytes: u64,
    /// Added latency once buffering falls back to host DRAM (Fig 10 jump).
    pub dram_spill_penalty: f64,
    /// Per-NIC message rate ceiling (messages/s) — Cassini ~ 2e8 for tiny
    /// messages; bounds all2all/incast throughput at small sizes.
    pub nic_msg_rate: f64,

    // ----- endpoint (node) constants (paper §2, §5.1) -----
    /// One rank cannot saturate a NIC (Fig 11/12): per-rank host-buffer
    /// issue ceiling. Two ranks/NIC reach ~23 GB/s effective.
    pub rank_issue_bw_host: f64,
    /// Per-rank issue ceiling with GPU-resident buffers (Fig 12).
    pub rank_issue_bw_gpu: f64,
    /// Effective NIC ceiling for host buffers (PCIe Gen4 x16 practical).
    pub nic_eff_bw_host: f64,
    /// Effective NIC ceiling for GPU buffers: PCIe Gen4<->Gen5 conversion
    /// inefficiency; 70/90 of host path (Fig 13 vs Fig 11, §5.1).
    pub nic_eff_bw_gpu: f64,
    /// Xe-Link GPU-GPU bandwidth, all-to-all on node (§2): 28 GB/s.
    pub xelink_bw: f64,
    /// PCIe Gen5 x16 CPU<->GPU bandwidth (§2): 64 GB/s.
    pub pcie5_bw: f64,
    /// CPU cores per socket (SPR: 52).
    pub cores_per_socket: usize,
    /// Sockets per node.
    pub sockets_per_node: usize,
    /// GPUs per node (PVC: 6).
    pub gpus_per_node: usize,
    /// HBM2e per node GB (2 CPUs x 64 + 6 GPUs x 128 = 896).
    pub hbm_per_node_gb: f64,
    /// DDR5 per node GB (2 x 512).
    pub ddr_per_node_gb: f64,
    /// Aggregate GPU HBM bandwidth per node (6 x ~3.28 TB/s), bytes/s.
    pub gpu_hbm_bw_node: f64,

    // ----- compute roofline (derived from paper §5.2) -----
    /// Node FP64 peak, flops. Derived: 1.012 EF/s at 9,234 nodes and
    /// 78.84% scaling efficiency (Table 2) => 139.0 TF/node peak.
    pub node_fp64_peak: f64,
    /// Node mixed-precision (bf16/fp16 MACC) peak; 11.64 EF/s at 9,500
    /// nodes (Fig 16) at ~51% of 2.4 PF/node.
    pub node_mxp_peak: f64,
    /// Fraction of FP64 peak a well-tuned GEMM achieves on PVC (HPL DGEMM).
    pub gemm_eff: f64,
    /// Fraction of MxP peak achieved by the bf16 GEMM.
    pub mxp_gemm_eff: f64,

    // ----- adaptive routing / congestion (paper §3.1, §4.2) -----
    /// Candidate minimal paths scored per flow (2 global links/pair).
    pub adaptive_candidates: usize,
    /// Load ratio above which a flow is diverted non-minimally (Valiant).
    pub nonminimal_threshold: f64,
    /// Routing bias toward minimal paths (§4.2.1): cost multiplier applied
    /// to non-minimal candidates.
    pub nonminimal_bias: f64,
    /// Enable group-load-aware intermediate group choice (§4.2.1).
    pub group_load_setting: bool,
    /// Incast fair-share back-pressure on contributors (§3.1).
    pub congestion_mgmt: bool,

    // ----- collectives (paper §5.1 Fig 14) -----
    /// Allreduce switches ring -> recursive-doubling tree below this size.
    pub allreduce_tree_cutoff: u64,
    /// Eager -> rendezvous protocol switch size.
    pub eager_threshold: u64,

    // ----- RMA / one-sided (paper §5.3.5, Tables 4-6) -----
    // PVC provides no hardware RMA; MPICH emulates it in software. The
    // per-op costs below are calibrated from the paper's own tables
    // (times / message counts; the per-node vs per-rank structure is what
    // the three-row scaling of each table implies).
    /// MPI_Get with HMEM: per-op cost on the *node-shared* progress engine
    /// (Table 5: 0.9s/1.6M = 1.1s/2.1M = 1.6s/2.8M ~ 0.55 us/op).
    pub rma_get_hmem_op: f64,
    /// MPI_Get without HMEM: staging through host serializes at the
    /// *origin rank* (Table 5: per-rank-op ~ 125-150 us, so total time
    /// DROPS as ranks grow — 24.6 -> 17.1 -> 13.0 s).
    pub rma_get_nohmem_op: f64,
    /// MPI_Put with HMEM: node engine ~ 8.2 us/op (Table 6).
    pub rma_put_hmem_op: f64,
    /// MPI_Put without HMEM: node engine ~ 18 us/op (Table 6).
    pub rma_put_nohmem_op: f64,
    /// Extra per-op cost when origin and target are on different nodes
    /// (Table 5 row 4: 9x16 sub-communicators, 19.2M msgs in 14.5 s,
    /// "an order of magnitude drop" vs intra-node).
    pub rma_internode_overhead: f64,
    /// Software RMA internal buffer: ops before MPI_Win_fence is REQUIRED
    /// (paper: fence every 2000 calls).
    pub rma_buffer_ops: usize,
    /// Put without HMEM overflows far earlier (paper: fence every 100
    /// "to prevent the communication failure").
    pub rma_buffer_ops_put_nohmem: usize,
}

impl AuroraConfig {
    /// Number of endpoints (NICs) in all compute groups.
    pub fn compute_endpoints(&self) -> usize {
        self.compute_groups * self.endpoints_per_group()
    }

    pub fn endpoints_per_group(&self) -> usize {
        self.switches_per_group * self.nodes_per_switch * self.nics_per_node
    }

    pub fn nodes(&self) -> usize {
        self.compute_groups * self.switches_per_group * self.nodes_per_switch
    }

    pub fn total_groups(&self) -> usize {
        self.compute_groups + self.storage_groups + self.service_groups
    }

    /// Total injection bandwidth across compute endpoints (paper Table 1:
    /// 2.12 PB/s for the full machine).
    pub fn injection_bw(&self) -> f64 {
        self.compute_endpoints() as f64 * self.nic_bw
    }

    /// Total global (inter-group) bandwidth, single direction counted per
    /// link pair as the paper does (Table 1: 1.37 PB/s => both directions
    /// of each of the ~27k compute-compute links).
    pub fn global_bw(&self) -> f64 {
        let g = self.compute_groups as f64;
        let links = g * (g - 1.0) / 2.0 * self.global_links_compute as f64;
        links * self.global_link_bw * 2.0
    }

    /// Global bisection bandwidth between compute groups (0.69 PB/s, both
    /// directions counted as in Table 1).
    pub fn global_bisection_bw(&self) -> f64 {
        // cut the machine in half: g/2 * g/2 pairs cross the cut
        let g = self.compute_groups as f64;
        let half = (g / 2.0).floor();
        half * (g - half) * self.global_links_compute as f64 * self.global_link_bw
            * 2.0
    }
}
