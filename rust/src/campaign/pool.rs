//! Deterministic fork-join maps over a persistent worker pool.
//!
//! The build is offline, so rayon is replaced by this small work-
//! stealing-free pool: workers pull item indices from an atomic counter
//! and write results into per-item slots, so the output order — and
//! therefore every byte of a campaign report — is identical no matter how
//! the OS schedules the workers. `tests/des_equivalence.rs` asserts
//! parallel == serial byte-for-byte.
//!
//! [`WorkerPool`] holds long-lived parked workers (condvar-blocked
//! between batches) so high-frequency dispatchers — the DES
//! component-parallel batch solve fans out thousands of event batches
//! per run — pay no `thread::spawn` per batch; [`par_map_on`] dispatches
//! one in-order map on such a pool. [`par_map_pooled`]/[`par_map_with`]/
//! [`par_map`] keep their historical one-shot semantics (the campaign
//! engine spawns once per campaign, where spawn cost is irrelevant).

use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of workers to use by default: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// State guarded by the pool mutex. `job` holds a lifetime-erased
/// reference to the current batch closure; see the safety argument on
/// [`WorkerPool::run`].
struct PoolState {
    /// Batch generation; bumped once per [`WorkerPool::run`].
    gen: u64,
    job: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Workers participating in the current batch (`0..participants`).
    participants: usize,
    /// Participants that have not yet finished the current batch.
    active: usize,
    shutdown: bool,
    panicked: bool,
}

struct Shared {
    m: Mutex<PoolState>,
    /// Workers wait here for a new generation (or shutdown).
    work_cv: Condvar,
    /// The dispatching caller waits here for `active == 0`.
    done_cv: Condvar,
}

/// Long-lived parked workers for repeated in-order fork-join maps.
/// Created once, reused for any number of [`par_map_on`] batches, joined
/// on drop. One batch runs at a time (`run` takes `&self` but the
/// caller blocks until the batch completes, so batches never overlap).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` parked worker threads (at least 1).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            m: Mutex::new(PoolState {
                gen: 0,
                job: None,
                participants: 0,
                active: 0,
                shutdown: false,
                panicked: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers.max(1))
            .map(|me| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh, me))
            })
            .collect();
        Self { shared, handles }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(w)` on workers `0..participants` and block until every
    /// participant returned.
    ///
    /// Safety of the lifetime erasure: the borrow of `f` is transmuted
    /// to `'static` so it can sit in the shared state, but this function
    /// only returns after `active` (set to `participants`) has been
    /// decremented to zero — i.e. after every call into the closure has
    /// finished — and the job slot is cleared before returning. Workers
    /// of a *previous* generation that wake late never touch it: a
    /// worker only calls the job of the generation it observed, the slot
    /// is `None` between batches, and a new generation cannot be posted
    /// while this one runs (the poster is blocked right here).
    fn run(&self, participants: usize, f: &(dyn Fn(usize) + Sync)) {
        debug_assert!(
            participants >= 1 && participants <= self.handles.len(),
            "participants out of range"
        );
        let job: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f) };
        let mut st = self.shared.m.lock().expect("pool mutex");
        debug_assert!(st.job.is_none(), "pool batches never overlap");
        st.gen = st.gen.wrapping_add(1);
        st.job = Some(job);
        st.participants = participants;
        st.active = participants;
        self.shared.work_cv.notify_all();
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).expect("pool mutex");
        }
        st.job = None;
        if st.panicked {
            // a worker died unwinding; the pool cannot guarantee further
            // batches complete — release everything and propagate
            st.shutdown = true;
            self.shared.work_cv.notify_all();
            drop(st);
            panic!("worker panicked during pooled batch");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.m.lock().expect("pool mutex");
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Decrements `active` when a participant finishes — including by
/// panic, so the dispatching caller can never deadlock on `done_cv`.
struct ActiveGuard<'a>(&'a Shared);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.0.m.lock().expect("pool mutex");
        if std::thread::panicking() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            self.0.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared, me: usize) {
    let mut seen = 0u64;
    loop {
        let job;
        {
            let mut st = shared.m.lock().expect("pool mutex");
            while !st.shutdown && (st.gen == seen || st.job.is_none()) {
                st = shared.work_cv.wait(st).expect("pool mutex");
            }
            if st.shutdown {
                return;
            }
            seen = st.gen;
            if me >= st.participants {
                continue; // not in this batch; drop the lock and re-park
            }
            job = st.job.expect("woken with a job");
        }
        let _guard = ActiveGuard(shared);
        job(me);
    }
}

/// Raw-pointer wrapper so disjoint per-worker / per-item writes can
/// cross the closure boundary. Safety is argued at each use site.
struct SendPtr<P>(*mut P);
unsafe impl<P> Send for SendPtr<P> {}
unsafe impl<P> Sync for SendPtr<P> {}

/// Map `f` over `items` with up to `threads` workers; results are in
/// input order. `threads <= 1` runs inline on the caller thread.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_with::<T, R, (), _>(items, threads, |t, _| f(t))
}

/// [`par_map`] with per-worker scratch state: every worker (or the
/// caller thread, when running inline) owns one `S::default()` and
/// threads it through its items — how campaign workers reuse one
/// [`crate::fabric::DesScratch`] solver arena across the scenarios they
/// execute instead of reallocating per scenario. `f` must produce
/// results independent of the scratch's history (the campaign
/// determinism suite asserts serial == parallel byte-for-byte, which
/// exercises exactly this property).
pub fn par_map_with<T, R, S, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Default + Send,
    F: Fn(&T, &mut S) -> R + Sync,
{
    par_map_pooled(items, threads, &mut Vec::new(), f)
}

/// [`par_map_with`] over *caller-owned* worker scratches: `scratches`
/// is grown to the worker count with `S::default()` and worker `w`
/// exclusively uses `scratches[w]`, so repeated calls reuse the same
/// warm arenas instead of re-building (and re-zeroing) per call.
/// Results are in input order; `f` must produce results independent of
/// scratch history, exactly as for [`par_map_with`]. Spawns a transient
/// [`WorkerPool`] per call — callers dispatching many small batches
/// should hold a pool and use [`par_map_on`] instead.
pub fn par_map_pooled<T, R, S, F>(
    items: &[T],
    threads: usize,
    scratches: &mut Vec<S>,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Default + Send,
    F: Fn(&T, &mut S) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 {
        if scratches.is_empty() {
            scratches.resize_with(1, S::default);
        }
        let scratch = &mut scratches[0];
        return items.iter().map(|t| f(t, scratch)).collect();
    }
    let pool = WorkerPool::new(threads);
    par_map_on(&pool, items, threads, scratches, f)
}

/// Make sure `slot` holds a pool of at least `threads` workers,
/// (re)creating it when absent or too small, and return it. How owners
/// of an optional lazily-built pool (the DES solver scratch) obtain
/// their pool right before a batch dispatch.
pub fn ensure_pool(
    slot: &mut Option<WorkerPool>,
    threads: usize,
) -> &WorkerPool {
    let need = threads.max(1);
    if slot.as_ref().map_or(true, |p| p.workers() < need) {
        *slot = Some(WorkerPool::new(need));
    }
    slot.as_ref().expect("pool just ensured")
}

/// [`par_map_pooled`] dispatched on a persistent [`WorkerPool`]: no
/// thread spawn, no per-item `Mutex` — results land in `MaybeUninit`
/// slots, each written exactly once (the atomic counter hands out every
/// index exactly once), and are collected in input order after the
/// batch barrier. Same determinism contract as [`par_map_pooled`].
pub fn par_map_on<T, R, S, F>(
    pool: &WorkerPool,
    items: &[T],
    threads: usize,
    scratches: &mut Vec<S>,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Default + Send,
    F: Fn(&T, &mut S) -> R + Sync,
{
    let threads = threads
        .clamp(1, items.len().max(1))
        .min(pool.workers());
    if scratches.len() < threads {
        scratches.resize_with(threads, S::default);
    }
    if threads <= 1 {
        let scratch = &mut scratches[0];
        return items.iter().map(|t| f(t, scratch)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<MaybeUninit<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), MaybeUninit::uninit);
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    let scratch_ptr = SendPtr(scratches.as_mut_ptr());
    {
        let next = &next;
        let job = move |w: usize| {
            // worker `w` exclusively owns scratches[w] (w < threads <=
            // scratches.len()); slot i is written exactly once because
            // the counter hands out each index exactly once
            let scratch: &mut S = unsafe { &mut *scratch_ptr.0.add(w) };
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i], scratch);
                unsafe { (*slots_ptr.0.add(i)).write(r) };
            }
        };
        pool.run(threads, &job);
    }
    // the barrier in run() guarantees every slot was initialized
    slots
        .into_iter()
        .map(|s| unsafe { s.assume_init() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 4, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_equals_parallel() {
        let items: Vec<u64> = (0..37).collect();
        let f = |&x: &u64| x.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(7);
        assert_eq!(par_map(&items, 1, f), par_map(&items, 8, f));
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], 4, |&x| x + 1), vec![6]);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1u32, 2, 3];
        assert_eq!(par_map(&items, 64, |&x| x), vec![1, 2, 3]);
    }

    #[test]
    fn pooled_scratches_persist_across_calls() {
        let items: Vec<u32> = (0..40).collect();
        let mut scratches: Vec<Vec<u32>> = Vec::new();
        let out1 = par_map_pooled(&items, 4, &mut scratches, |&x, s| {
            s.push(x); // scratch history must not affect results
            x + 1
        });
        assert_eq!(scratches.len(), 4, "one scratch per worker");
        let warmed: Vec<usize> =
            scratches.iter().map(Vec::capacity).collect();
        assert!(warmed.iter().any(|&c| c > 0));
        let out2 = par_map_pooled(&items, 4, &mut scratches, |&x, s| {
            s.clear();
            s.push(x);
            x + 1
        });
        assert_eq!(out1, out2);
        assert_eq!(out1, (1..=40).collect::<Vec<_>>());
        assert_eq!(scratches.len(), 4, "pool must not grow on reuse");
    }

    #[test]
    fn persistent_pool_reused_across_batches() {
        let pool = WorkerPool::new(4);
        let mut scratches: Vec<()> = Vec::new();
        for round in 0..50u64 {
            let items: Vec<u64> = (0..31).collect();
            let out = par_map_on(&pool, &items, 4, &mut scratches, |&x, _| {
                x.wrapping_mul(round + 1)
            });
            let want: Vec<u64> =
                (0..31).map(|x| x * (round + 1)).collect();
            assert_eq!(out, want, "round {round}");
        }
        assert_eq!(pool.workers(), 4);
    }

    #[test]
    fn par_map_on_clamps_to_pool_and_items() {
        let pool = WorkerPool::new(2);
        let mut scratches: Vec<()> = Vec::new();
        // more threads requested than the pool has: clamped, in order
        let items: Vec<u32> = (0..9).collect();
        let out = par_map_on(&pool, &items, 16, &mut scratches, |&x, _| x);
        assert_eq!(out, items);
        assert!(scratches.len() <= 2);
        // single item runs inline
        let one = par_map_on(&pool, &[7u32], 8, &mut scratches, |&x, _| x + 1);
        assert_eq!(one, vec![8]);
        // empty input
        let none: Vec<u32> =
            par_map_on(&pool, &[] as &[u32], 8, &mut scratches, |&x, _| x);
        assert!(none.is_empty());
    }

    #[test]
    fn ensure_pool_grows_but_never_shrinks() {
        let mut slot: Option<WorkerPool> = None;
        assert_eq!(ensure_pool(&mut slot, 2).workers(), 2);
        assert_eq!(ensure_pool(&mut slot, 2).workers(), 2);
        // larger request: rebuilt
        assert_eq!(ensure_pool(&mut slot, 5).workers(), 5);
        // smaller request: the existing pool is big enough, kept
        assert_eq!(ensure_pool(&mut slot, 3).workers(), 5);
        assert_eq!(ensure_pool(&mut slot, 0).workers(), 5);
    }
}
