//! Deterministic fork-join map over `std::thread::scope`.
//!
//! The build is offline, so rayon is replaced by this ~50-line work-
//! stealing-free pool: workers pull item indices from an atomic counter
//! and write results into per-item slots, so the output order — and
//! therefore every byte of a campaign report — is identical no matter how
//! the OS schedules the workers. `tests/des_equivalence.rs` asserts
//! parallel == serial byte-for-byte.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use by default: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` with up to `threads` workers; results are in
/// input order. `threads <= 1` runs inline on the caller thread.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_with::<T, R, (), _>(items, threads, |t, _| f(t))
}

/// [`par_map`] with per-worker scratch state: every worker (or the
/// caller thread, when running inline) owns one `S::default()` and
/// threads it through its items — how campaign workers reuse one
/// [`crate::fabric::DesScratch`] solver arena across the scenarios they
/// execute instead of reallocating per scenario. `f` must produce
/// results independent of the scratch's history (the campaign
/// determinism suite asserts serial == parallel byte-for-byte, which
/// exercises exactly this property).
pub fn par_map_with<T, R, S, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Default + Send,
    F: Fn(&T, &mut S) -> R + Sync,
{
    par_map_pooled(items, threads, &mut Vec::new(), f)
}

/// [`par_map_with`] over *caller-owned* worker scratches: `scratches`
/// is grown to the worker count with `S::default()` and worker `w`
/// exclusively uses `scratches[w]`, so repeated calls reuse the same
/// warm arenas instead of re-building (and re-zeroing) per call — how
/// the DES component-parallel batch solve keeps its per-worker
/// `CompScratch` across thousands of event batches. Results are in
/// input order; `f` must produce results independent of scratch
/// history, exactly as for [`par_map_with`].
pub fn par_map_pooled<T, R, S, F>(
    items: &[T],
    threads: usize,
    scratches: &mut Vec<S>,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Default + Send,
    F: Fn(&T, &mut S) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if scratches.len() < threads {
        scratches.resize_with(threads, S::default);
    }
    if threads <= 1 {
        let scratch = &mut scratches[0];
        return items.iter().map(|t| f(t, scratch)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        let next = &next;
        let slots = &slots;
        let f = &f;
        for scratch in scratches.iter_mut().take(threads) {
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i], scratch);
                *slots[i].lock().expect("poisoned result slot") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("poisoned result slot")
                .expect("worker filled every slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 4, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_equals_parallel() {
        let items: Vec<u64> = (0..37).collect();
        let f = |&x: &u64| x.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(7);
        assert_eq!(par_map(&items, 1, f), par_map(&items, 8, f));
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], 4, |&x| x + 1), vec![6]);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1u32, 2, 3];
        assert_eq!(par_map(&items, 64, |&x| x), vec![1, 2, 3]);
    }

    #[test]
    fn pooled_scratches_persist_across_calls() {
        let items: Vec<u32> = (0..40).collect();
        let mut scratches: Vec<Vec<u32>> = Vec::new();
        let out1 = par_map_pooled(&items, 4, &mut scratches, |&x, s| {
            s.push(x); // scratch history must not affect results
            x + 1
        });
        assert_eq!(scratches.len(), 4, "one scratch per worker");
        let warmed: Vec<usize> =
            scratches.iter().map(Vec::capacity).collect();
        assert!(warmed.iter().any(|&c| c > 0));
        let out2 = par_map_pooled(&items, 4, &mut scratches, |&x, s| {
            s.clear();
            s.push(x);
            x + 1
        });
        assert_eq!(out1, out2);
        assert_eq!(out1, (1..=40).collect::<Vec<_>>());
        assert_eq!(scratches.len(), 4, "pool must not grow on reuse");
    }
}
