//! Multi-scenario campaign engine: run many named fabric scenarios in
//! parallel, deterministically, and emit a machine-readable JSON report.
//!
//! The paper validates Aurora's fabric by sweeping many workloads —
//! GPCNet isolated/congested (§3.8.2), incast fan-ins (§3.1), degraded
//! lanes (§3.4), collective rounds (§5.1) — over many configurations.
//! [`Campaign`] packages such a sweep: each [`Scenario`] is self-
//! contained (own topology, router, DES, name-derived seed), so the
//! engine can fan scenarios out over a [`pool::par_map`] worker pool and
//! still produce byte-identical reports in serial and parallel runs.
//!
//! ```no_run
//! use aurorasim::campaign::Campaign;
//! use aurorasim::config::AuroraConfig;
//!
//! let c = Campaign::standard(&AuroraConfig::small(8, 4), 0xA112a);
//! let report = c.run(aurorasim::campaign::pool::default_threads());
//! println!("{}", report.render_table());
//! std::fs::write("campaign.json", report.to_json().dump_pretty()).unwrap();
//! ```

pub mod pool;
pub mod scenario;

pub use scenario::{PhaseApp, Scenario, ScenarioResult, Workload};

use crate::config::AuroraConfig;
use crate::fabric::arrivals::RpcClass;
use crate::fabric::degrade::{brownout_policy, ServicePolicy};
use crate::fabric::des::DesOpts;
use crate::fabric::faults::{FaultKind, FaultPolicy, FaultSchedule};
use crate::metrics::table;
use crate::topology::{LinkId, Topology};
use crate::runtime::manifest::RunInfo;
use crate::util::Json;
use anyhow::Result;

/// JSON schema tag stamped onto campaign reports. v2: closed-loop rows
/// report their contention-free dependency reference in an explicit
/// `critical_path_s` field instead of overloading `rounds_upper_s`
/// (which is now 0 for closed-loop rows and vice versa). v3: every row
/// gains a `steady_state` member — an object (arrivals, completed,
/// duration_s, throughput, p50/p99/p999, per-class max_backlog,
/// peak_live, windows) for open-loop *service* scenarios
/// ([`Workload::OpenLoop`]), `null` for batch and closed-loop rows.
/// v4: every row gains `failed_flows` and `aborted_nodes` counters and
/// a nullable `faults` block — `{policy, events: [{t_s, kind,
/// target}]}` — describing the fault timeline the scenario priced
/// (`null` when fault-free). v5: `steady_state` gains a per-class
/// `failed` array (fault-failed requests retired from the backlog,
/// excluded from the quantiles) and every row gains a nullable
/// `degradation` block — `{policy, accepted, shed, abandoned, failed,
/// hedged, deadline_met, goodput_flows_per_s}` — present exactly when
/// the scenario armed a [`crate::fabric::ServicePolicy`]; see
/// EXPERIMENTS.md §Campaign schema.
pub const CAMPAIGN_SCHEMA: &str = "aurorasim.campaign/v5";

/// The RPC size mix shared by the open-loop service scenarios: mostly
/// small control-plane messages, some medium payloads, a thin tail of
/// 1 MiB bulk transfers. The entry index is the service class reported
/// in `steady_state.max_backlog`.
fn rpc_mix() -> Vec<RpcClass> {
    vec![
        RpcClass { bytes: 4 << 10, weight: 0.70 },
        RpcClass { bytes: 64 << 10, weight: 0.25 },
        RpcClass { bytes: 1 << 20, weight: 0.05 },
    ]
}

/// A named set of scenarios executed as one unit.
#[derive(Debug, Clone, Default)]
pub struct Campaign {
    pub scenarios: Vec<Scenario>,
}

impl Campaign {
    pub fn new() -> Self {
        Self { scenarios: Vec::new() }
    }

    pub fn push(&mut self, s: Scenario) {
        self.scenarios.push(s);
    }

    /// The standard scenario suite: GPCNet isolated/congested (with and
    /// without congestion management), incast fan-ins, uniform and
    /// permutation/ring collective rounds, a degraded-lane sweep and a
    /// staggered-arrival mix, plus the closed-loop (dependency-released)
    /// scenarios — collective-vs-incast interference, phase-staggered
    /// multi-job, degraded-lane collective, the HACC / AMR-Wind /
    /// LAMMPS step traces, and the multi-group halo+allreduce step —
    /// plus the open-loop *service* scenarios (Poisson RPC mixes on the
    /// bounded-memory streaming tier, healthy and degraded-link), plus
    /// the chaos scenarios (deterministic mid-run fault timelines:
    /// a flapping global link under the closed-loop halo+allreduce
    /// step, a NIC outage mid-ring priced through retry-backoff, and a
    /// random-flap open-loop service day whose p99 reads against
    /// `open_loop_rpc`'s healthy baseline), plus the brownout twin of
    /// that service day (same fault timeline with a shed+deadline+budget
    /// [`ServicePolicy`] armed — schema v5's `degradation` block reads
    /// directly against `chaos_service_flaps`) —
    /// 23 scenarios on the given config (needs >= 4 compute groups).
    pub fn standard(cfg: &AuroraConfig, seed: u64) -> Self {
        let on = DesOpts::default();
        let off = DesOpts { congestion_mgmt: false, ..DesOpts::default() };
        let mk = |name: &str, opts: &DesOpts, w: Workload| {
            Scenario::new(name, cfg.clone(), opts.clone(), w, seed)
        };
        // ---- chaos fault timelines (campaign schema v4) ----
        // flapping inter-group link: two down/recover cycles on the
        // first parallel global link between groups 0 and 1, rerouting
        // in-flight flows onto the surviving parallel link
        let flap_link = LinkId::Global { src: 0, dst: 1, idx: 0 };
        let flapping = FaultSchedule::new(FaultPolicy::Reroute)
            .at(50e-6, FaultKind::LinkDown { link: flap_link })
            .at(150e-6, FaultKind::LinkRecover { link: flap_link })
            .at(250e-6, FaultKind::LinkDown { link: flap_link })
            .at(350e-6, FaultKind::LinkRecover { link: flap_link });
        let chaos_flap = DesOpts { faults: Some(flapping), ..on.clone() };
        // NIC outage mid-ring: endpoint 5's NIC dies and comes back;
        // the two ring flows touching it re-arrive via retry-backoff
        // (3 attempts at 25/50/100 us clear the 100 us outage)
        let nic_outage = FaultSchedule::new(FaultPolicy::RetryBackoff {
            timeout: 25e-6,
            backoff: 2.0,
            max_retries: 10,
        })
        .at(100e-6, FaultKind::NicDown { endpoint: 5 })
        .at(200e-6, FaultKind::LinkRecover { link: LinkId::NicUp(5) })
        .at(200e-6, FaultKind::LinkRecover { link: LinkId::NicDown(5) });
        let chaos_nic = DesOpts { faults: Some(nic_outage), ..on.clone() };
        // random global-link flaps over the first ~0.8 s of a 1 s
        // service run (seeded on the dedicated fault stream)
        let topo = Topology::new(cfg);
        let flaps = FaultSchedule::random_flaps(
            &topo,
            6,
            0.8,
            0.05,
            seed,
            FaultPolicy::Reroute,
        );
        let chaos_service =
            DesOpts { faults: Some(flaps.clone()), ..on.clone() };
        // the brownout twin arms a shed+deadline+budget policy over the
        // *same* fault timeline: the v5 degradation block of this row
        // reads directly against chaos_service_flaps' unprotected one
        let chaos_brownout = DesOpts {
            faults: Some(flaps),
            policies: Some(brownout_policy(&rpc_mix(), 1024, 20e-3, 10_000.0)),
            ..on.clone()
        };
        Self {
            scenarios: vec![
                mk("gpcnet_isolated", &on,
                   Workload::GpcnetMix {
                       victims: 64, congestors: 0, bytes: 128 << 10,
                   }),
                mk("gpcnet_congested", &on,
                   Workload::GpcnetMix {
                       victims: 64, congestors: 32, bytes: 128 << 10,
                   }),
                mk("gpcnet_congested_nocm", &off,
                   Workload::GpcnetMix {
                       victims: 64, congestors: 32, bytes: 128 << 10,
                   }),
                mk("incast_8x16", &on,
                   Workload::Incast { roots: 8, fanin: 16, bytes: 8 << 20 }),
                mk("incast_8x16_nocm", &off,
                   Workload::Incast { roots: 8, fanin: 16, bytes: 8 << 20 }),
                mk("uniform_512", &on,
                   Workload::UniformRandom { flows: 512, bytes: 1 << 20 }),
                mk("permutation_256", &on,
                   Workload::Permutation { pairs: 256, bytes: 4 << 20 }),
                mk("ring_256", &on,
                   Workload::Ring { ranks: 256, bytes: 16 << 20 }),
                mk("degraded_half_bw", &on,
                   Workload::Degraded {
                       flows: 256,
                       bytes: 2 << 20,
                       bw_multiplier: 0.5,
                       link_fraction: 0.25,
                   }),
                mk("staggered_256", &on,
                   Workload::Staggered {
                       flows: 256, bytes: 1 << 20, window_s: 0.05,
                   }),
                // ---- closed-loop (dependency-released) scenarios ----
                mk("coll_vs_incast", &on,
                   Workload::CollectiveIncast {
                       ranks: 32,
                       rounds: 12,
                       bytes: 1 << 20,
                       fanin: 12,
                       congestor_bytes: 8 << 20,
                   }),
                mk("phase_staggered_3job", &on,
                   Workload::PhaseStaggered {
                       jobs: 3,
                       ranks: 16,
                       rounds: 10,
                       bytes: 2 << 20,
                       stagger_s: 1e-3,
                   }),
                mk("degraded_ring_closed", &on,
                   Workload::DegradedCollective {
                       ranks: 32,
                       rounds: 12,
                       bytes: 2 << 20,
                       bw_multiplier: 0.5,
                       link_fraction: 0.5,
                   }),
                mk("hacc_step_closed", &on,
                   Workload::AppPhase {
                       app: PhaseApp::Hacc, ranks: 24, bytes: 8 << 20,
                   }),
                mk("amr_wind_step_closed", &on,
                   Workload::AppPhase {
                       app: PhaseApp::AmrWind, ranks: 24, bytes: 1 << 20,
                   }),
                mk("lammps_step_closed", &on,
                   Workload::AppPhase {
                       app: PhaseApp::Lammps, ranks: 24, bytes: 8 << 20,
                   }),
                mk("halo_allreduce_closed", &on,
                   Workload::HaloAllreduce {
                       groups: 4,
                       ranks_per_group: 8,
                       halo_rounds: 3,
                       bytes: 1 << 20,
                       leader_rounds: 4,
                       leader_bytes: 2 << 20,
                   }),
                // ---- open-loop service tier (fabric::arrivals) ----
                mk("open_loop_rpc", &on,
                   Workload::OpenLoop {
                       arrivals: 200_000,
                       rate: 100_000.0,
                       endpoints: 256,
                       mix: rpc_mix(),
                       quantum: 1e-3,
                       window: 50e-3,
                       bw_multiplier: 1.0,
                       link_fraction: 0.0,
                   }),
                mk("open_loop_degraded", &on,
                   Workload::OpenLoop {
                       arrivals: 120_000,
                       rate: 60_000.0,
                       endpoints: 256,
                       mix: rpc_mix(),
                       quantum: 1e-3,
                       window: 50e-3,
                       bw_multiplier: 0.5,
                       link_fraction: 0.25,
                   }),
                // ---- chaos: deterministic mid-run fault timelines ----
                mk("chaos_flap_halo_closed", &chaos_flap,
                   Workload::HaloAllreduce {
                       groups: 4,
                       ranks_per_group: 8,
                       halo_rounds: 3,
                       bytes: 1 << 20,
                       leader_rounds: 4,
                       leader_bytes: 2 << 20,
                   }),
                mk("chaos_nic_retry_ring", &chaos_nic,
                   Workload::Ring { ranks: 64, bytes: 8 << 20 }),
                mk("chaos_service_flaps", &chaos_service,
                   Workload::OpenLoop {
                       arrivals: 60_000,
                       rate: 60_000.0,
                       endpoints: 256,
                       mix: rpc_mix(),
                       quantum: 1e-3,
                       window: 50e-3,
                       bw_multiplier: 1.0,
                       link_fraction: 0.0,
                   }),
                mk("chaos_service_brownout", &chaos_brownout,
                   Workload::OpenLoop {
                       arrivals: 60_000,
                       rate: 60_000.0,
                       endpoints: 256,
                       mix: rpc_mix(),
                       quantum: 1e-3,
                       window: 50e-3,
                       bw_multiplier: 1.0,
                       link_fraction: 0.0,
                   }),
            ],
        }
    }

    /// The chaos sweep behind the `aurorasim chaos` CLI verb: fault
    /// rate (flap count over a fixed horizon) x [`FaultPolicy`] on the
    /// closed-loop multi-group halo+allreduce step — 9 scenarios whose
    /// reports surface how each policy prices the same outage pattern
    /// (reroute absorbs it, retry-backoff delays it, abort gives up and
    /// reports `failed_flows`/`aborted_nodes`). Every cell's fault
    /// schedule is seeded from the campaign seed and the cell name, so
    /// the sweep is deterministic and byte-identical across
    /// `DES_THREADS` settings.
    pub fn chaos(cfg: &AuroraConfig, seed: u64) -> Self {
        let topo = Topology::new(cfg);
        let policies = [
            FaultPolicy::Reroute,
            FaultPolicy::RetryBackoff {
                timeout: 25e-6,
                backoff: 2.0,
                max_retries: 8,
            },
            FaultPolicy::Abort,
        ];
        let mut c = Self::new();
        for policy in policies {
            for flaps in [2usize, 6, 12] {
                let name =
                    format!("chaos_{}_{}flaps", policy.name(), flaps);
                let fs = FaultSchedule::random_flaps(
                    &topo,
                    flaps,
                    400e-6,
                    100e-6,
                    seed ^ scenario::fnv1a(&name),
                    policy,
                );
                let opts =
                    DesOpts { faults: Some(fs), ..DesOpts::default() };
                c.push(Scenario::new(
                    &name,
                    cfg.clone(),
                    opts,
                    Workload::HaloAllreduce {
                        groups: 4,
                        ranks_per_group: 8,
                        halo_rounds: 3,
                        bytes: 1 << 20,
                        leader_rounds: 4,
                        leader_bytes: 2 << 20,
                    },
                    seed,
                ));
            }
        }
        c
    }

    /// The brownout sweep behind the `aurorasim brownout` CLI verb:
    /// fault rate (flap count over the service run) x overload policy on
    /// the same Poisson RPC service — 9 scenarios (3 flap counts x
    /// {`off`, `shed`, `full`}) whose schema-v5 `degradation` blocks
    /// show what each control family buys as the fault rate climbs:
    /// `off` arms nothing (the unprotected baseline whose backlog grows
    /// with the outage), `shed` arms admission control only (backlog
    /// threshold), `full` arms shed + deadline + retry budget
    /// ([`brownout_policy`]). Faults run under retry-backoff so the
    /// budget is actually consumed. Every cell's fault schedule is
    /// seeded from the campaign seed and the cell name — deterministic
    /// and byte-identical across `DES_THREADS` settings, which the
    /// campaign-determinism CI job asserts.
    pub fn brownout(cfg: &AuroraConfig, seed: u64) -> Self {
        let topo = Topology::new(cfg);
        let mix = rpc_mix();
        let policies: [(&str, Option<ServicePolicy>); 3] = [
            ("off", None),
            ("shed", Some(brownout_policy(
                &mix, 256, f64::INFINITY, f64::INFINITY,
            ))),
            ("full", Some(brownout_policy(&mix, 256, 10e-3, 2_000.0))),
        ];
        let mut c = Self::new();
        for (pname, policy) in &policies {
            for flaps in [2usize, 6, 12] {
                let name = format!("brownout_{pname}_{flaps}flaps");
                let fs = FaultSchedule::random_flaps(
                    &topo,
                    flaps,
                    0.6,
                    0.05,
                    seed ^ scenario::fnv1a(&name),
                    FaultPolicy::RetryBackoff {
                        timeout: 25e-6,
                        backoff: 2.0,
                        max_retries: 8,
                    },
                );
                let opts = DesOpts {
                    faults: Some(fs),
                    policies: policy.clone(),
                    ..DesOpts::default()
                };
                c.push(Scenario::new(
                    &name,
                    cfg.clone(),
                    opts,
                    Workload::OpenLoop {
                        arrivals: 40_000,
                        rate: 50_000.0,
                        endpoints: 128,
                        mix: mix.clone(),
                        quantum: 1e-3,
                        window: 25e-3,
                        bw_multiplier: 1.0,
                        link_fraction: 0.0,
                    },
                    seed,
                ));
            }
        }
        c
    }

    /// The full-Aurora-scale open-loop service sweep (ROADMAP item 2's
    /// headline): one million Poisson RPC arrivals over 2,048 endpoints
    /// spread across the whole [`AuroraConfig::full_aurora`] machine,
    /// streamed at bounded memory with windowed steady-state metrics.
    /// Kept out of [`Campaign::standard`] for the same reason as
    /// [`Campaign::full_aurora`]: a million-arrival full-machine run is
    /// CI/bench-scale, not unit-test-scale. The `aurorasim openloop` CLI
    /// runs it; the campaign-determinism CI job byte-diffs its report
    /// across serial and `DES_THREADS=8` runs, and the
    /// `des_open_loop_steady` bench enforces the
    /// `open_loop_live_headroom` peak-live floor on it.
    pub fn open_loop_aurora(seed: u64) -> Self {
        Self {
            scenarios: vec![Scenario::new(
                "open_loop_rpc_aurora",
                AuroraConfig::full_aurora(),
                DesOpts::default(),
                Workload::OpenLoop {
                    arrivals: 1_000_000,
                    rate: 400_000.0,
                    endpoints: 2_048,
                    mix: rpc_mix(),
                    quantum: 1e-3,
                    window: 100e-3,
                    bw_multiplier: 1.0,
                    link_fraction: 0.0,
                },
                seed,
            )],
        }
    }

    /// The full-Aurora-scale sweep: the multi-group halo+allreduce step
    /// over 128 group-aligned blocks of 128 endpoints — 16,384 simulated
    /// endpoints on [`AuroraConfig::full_aurora`] — with the DES batch
    /// solve fanned out over all available cores. This is the
    /// `des_component_parallel_full_aurora` bench workload; it is kept
    /// out of [`Campaign::standard`] because a full-machine DES run is
    /// bench-scale, not unit-test-scale.
    pub fn full_aurora(seed: u64) -> Self {
        let cfg = AuroraConfig::full_aurora();
        let opts = DesOpts {
            solver_threads: pool::default_threads(),
            ..DesOpts::default()
        };
        Self {
            scenarios: vec![Scenario::new(
                "full_aurora_halo_allreduce",
                cfg,
                opts,
                Workload::HaloAllreduce {
                    groups: 128,
                    ranks_per_group: 128,
                    halo_rounds: 2,
                    bytes: 1 << 20,
                    leader_rounds: 8,
                    leader_bytes: 4 << 20,
                },
                seed,
            )],
        }
    }

    /// Execute every scenario on up to `threads` workers. Results are in
    /// scenario order and independent of scheduling, so
    /// `run(1)` and `run(k)` produce identical reports. Each worker owns
    /// one [`crate::fabric::DesScratch`] solver arena reused across the
    /// scenarios it executes (a scenario's thousands of DES events then
    /// run allocation-free after the first); scenario results are
    /// scratch-history-independent, so this cannot perturb determinism.
    pub fn run(&self, threads: usize) -> CampaignReport {
        let results =
            pool::par_map_with(&self.scenarios, threads, Scenario::run_with);
        CampaignReport { results }
    }

    /// Serial convenience (the determinism baseline).
    pub fn run_serial(&self) -> CampaignReport {
        self.run(1)
    }
}

/// Results of an executed campaign, in scenario order.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignReport {
    pub results: Vec<ScenarioResult>,
}

impl CampaignReport {
    /// Deterministic JSON (provenance header + per-scenario metrics).
    /// Excludes wall-clock anything: equal campaigns serialize to equal
    /// bytes.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("info", RunInfo::new(CAMPAIGN_SCHEMA).to_json()),
            (
                "scenarios",
                Json::arr(
                    self.results.iter().map(ScenarioResult::to_json).collect(),
                ),
            ),
        ])
    }

    /// Write the pretty JSON report to `path`.
    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().dump_pretty())?;
        Ok(())
    }

    /// Human-readable summary table.
    pub fn render_table(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .results
            .iter()
            .map(|r| {
                let (thru, sp99) = match &r.steady_state {
                    Some(ss) => (
                        format!("{:.0}", ss.throughput_flows),
                        format!("{:.3}", ss.p99 * 1e3),
                    ),
                    None => ("-".to_string(), "-".to_string()),
                };
                vec![
                    r.name.clone(),
                    r.flows.to_string(),
                    format!("{:.3}", r.makespan * 1e3),
                    format!("{:.3}", r.p99_finish * 1e3),
                    r.contributors.to_string(),
                    r.victims.to_string(),
                    format!("{:.3}", r.rounds_upper * 1e3),
                    format!("{:.3}", r.critical_path * 1e3),
                    thru,
                    sp99,
                ]
            })
            .collect();
        table(
            &[
                "scenario",
                "flows",
                "makespan ms",
                "p99 ms",
                "contrib",
                "victims",
                "rounds-UB ms",
                "crit-path ms",
                "svc-thru f/s",
                "svc-p99 ms",
            ],
            &rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_campaign() -> Campaign {
        let cfg = AuroraConfig::small(4, 4);
        let mut c = Campaign::new();
        c.push(Scenario::new(
            "a",
            cfg.clone(),
            DesOpts::default(),
            Workload::Incast { roots: 1, fanin: 8, bytes: 2 << 20 },
            9,
        ));
        c.push(Scenario::new(
            "b",
            cfg.clone(),
            DesOpts::default(),
            Workload::UniformRandom { flows: 24, bytes: 1 << 20 },
            9,
        ));
        c.push(Scenario::new(
            "c",
            cfg.clone(),
            DesOpts::default(),
            Workload::Ring { ranks: 32, bytes: 4 << 20 },
            9,
        ));
        c.push(Scenario::new(
            "d_open_loop",
            cfg.clone(),
            DesOpts::default(),
            Workload::OpenLoop {
                arrivals: 2_000,
                rate: 40_000.0,
                endpoints: 32,
                mix: rpc_mix(),
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 1.0,
                link_fraction: 0.0,
            },
            9,
        ));
        c.push(Scenario::new(
            "e_brownout",
            cfg,
            DesOpts {
                policies: Some(brownout_policy(
                    &rpc_mix(),
                    1024,
                    20e-3,
                    1_000.0,
                )),
                ..DesOpts::default()
            },
            Workload::OpenLoop {
                arrivals: 2_000,
                rate: 40_000.0,
                endpoints: 32,
                mix: rpc_mix(),
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 1.0,
                link_fraction: 0.0,
            },
            9,
        ));
        c
    }

    #[test]
    fn parallel_report_is_byte_identical_to_serial() {
        let c = tiny_campaign();
        let serial = c.run_serial().to_json().dump_pretty();
        let parallel = c.run(3).to_json().dump_pretty();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn standard_suite_has_at_least_eight_scenarios() {
        let c = Campaign::standard(&AuroraConfig::small(4, 4), 1);
        assert!(c.scenarios.len() >= 8, "{}", c.scenarios.len());
        // all names unique (seeds are name-derived)
        let mut names: Vec<&str> =
            c.scenarios.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), c.scenarios.len());
    }

    #[test]
    fn full_aurora_campaign_is_full_machine_scale() {
        // construction-level checks only: executing 16,384 endpoints is
        // bench-scale (des_component_parallel_full_aurora), not test-scale
        let c = Campaign::full_aurora(7);
        assert_eq!(c.scenarios.len(), 1);
        let s = &c.scenarios[0];
        assert!(s.is_closed_loop());
        assert_eq!(s.cfg.compute_endpoints(), 84_992);
        match s.workload {
            Workload::HaloAllreduce { groups, ranks_per_group, .. } => {
                assert!(
                    groups * ranks_per_group >= 16_384,
                    "full-aurora scenario must simulate >= 16,384 endpoints"
                );
            }
            _ => panic!("full-aurora scenario must be HaloAllreduce"),
        }
        assert!(s.opts.solver_threads >= 1);
    }

    #[test]
    fn report_json_parses_and_carries_schema() {
        let c = tiny_campaign();
        let rep = c.run(2);
        let j = Json::parse(&rep.to_json().dump_pretty()).unwrap();
        assert_eq!(
            j.get("info").and_then(|i| i.get("schema")).and_then(Json::as_str),
            Some(CAMPAIGN_SCHEMA)
        );
        assert_eq!(j.get("scenarios").and_then(Json::as_arr).unwrap().len(), 5);
        // the open-loop row carries a steady_state object, batch rows null
        let rows = j.get("scenarios").and_then(Json::as_arr).unwrap();
        assert_eq!(rows[0].get("steady_state"), Some(&Json::Null));
        let ss = rows[3].get("steady_state").unwrap();
        assert_ne!(ss, &Json::Null);
        assert_eq!(
            ss.get("arrivals").and_then(Json::as_f64),
            Some(2_000.0)
        );
        // schema v5: per-class failed counts in steady_state, and a
        // degradation block exactly on policy-armed rows
        assert!(ss.get("failed").is_some());
        assert_eq!(rows[0].get("degradation"), Some(&Json::Null));
        assert_eq!(rows[3].get("degradation"), Some(&Json::Null));
        let deg = rows[4].get("degradation").unwrap();
        assert_ne!(deg, &Json::Null);
        assert_eq!(
            deg.get("policy").and_then(Json::as_str),
            Some("shed-deadline-budget")
        );
        assert_eq!(deg.get("accepted").and_then(Json::as_f64), Some(2_000.0));
        assert!(deg.get("goodput_flows_per_s").is_some());
        // nothing sheds/abandons on a healthy uncongested run: goodput
        // equals throughput and the counters stay zero
        let zeros = |key: &str| {
            deg.get(key)
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .all(|v| v.as_f64() == Some(0.0))
        };
        assert!(zeros("shed") && zeros("abandoned") && zeros("failed"));
    }

    #[test]
    fn brownout_sweep_is_a_policy_by_fault_rate_grid() {
        let c = Campaign::brownout(&AuroraConfig::small(4, 4), 3);
        assert_eq!(c.scenarios.len(), 9);
        for s in &c.scenarios {
            assert!(s.is_open_loop(), "{}", s.name);
            assert!(s.opts.faults.is_some(), "{}", s.name);
            let armed = s.opts.policies.is_some();
            assert_eq!(
                armed,
                !s.name.contains("_off_"),
                "{}: policy presence must follow the cell name",
                s.name
            );
        }
        // cell fault schedules differ (name-derived seeds)
        let e0 = &c.scenarios[0].opts.faults.as_ref().unwrap().events;
        let e1 = &c.scenarios[1].opts.faults.as_ref().unwrap().events;
        assert_ne!(
            format!("{e0:?}"),
            format!("{e1:?}"),
            "cells must not share one fault timeline"
        );
    }

    #[test]
    fn standard_suite_includes_open_loop_service_scenarios() {
        let c = Campaign::standard(&AuroraConfig::small(4, 4), 1);
        let open: Vec<&str> = c
            .scenarios
            .iter()
            .filter(|s| s.is_open_loop())
            .map(|s| s.name.as_str())
            .collect();
        assert!(open.len() >= 2, "{open:?}");
        assert!(open.contains(&"open_loop_rpc"));
        assert!(open.contains(&"open_loop_degraded"));
    }

    #[test]
    fn open_loop_aurora_campaign_is_million_arrival_full_machine() {
        // construction-level checks only: a million-arrival full-machine
        // run is bench/CI-scale (des_open_loop_steady), not test-scale
        let c = Campaign::open_loop_aurora(7);
        assert_eq!(c.scenarios.len(), 1);
        let s = &c.scenarios[0];
        assert!(s.is_open_loop());
        assert_eq!(s.cfg.compute_endpoints(), 84_992);
        match &s.workload {
            Workload::OpenLoop { arrivals, endpoints, .. } => {
                assert!(*arrivals >= 1_000_000);
                assert!(*endpoints >= 2_048);
            }
            _ => panic!("open_loop_aurora scenario must be OpenLoop"),
        }
    }

    #[test]
    fn table_lists_every_scenario() {
        let c = tiny_campaign();
        let t = c.run(2).render_table();
        for s in &c.scenarios {
            assert!(t.contains(&s.name), "{t}");
        }
    }
}
