//! Named scenarios: a config x DES options x workload generator, with a
//! deterministic per-scenario seed.
//!
//! A scenario is fully self-contained — it builds its own topology,
//! routes its own flows and runs its own DES — so the campaign engine can
//! execute any number of them concurrently with no shared mutable state,
//! and the result depends only on the scenario (never on scheduling).

use crate::config::AuroraConfig;
use crate::fabric::analysis::{AnalysisReport, WorkloadAnalyzer};
use crate::fabric::arrivals::{
    run_open_loop, OpenLoopSource, PoissonArrivals, RpcClass, SteadyState,
};
use crate::fabric::degrade::ServicePolicy;
use crate::fabric::des::{DesOpts, DesScratch, DesSim, TimedFlow};
use crate::fabric::faults::{FaultEvent, FaultKind, FaultSchedule};
use crate::fabric::rounds::CostModel;
use crate::fabric::workload::{self, DagBuilder, DagKind, DagWorkload};
use crate::fabric::{Flow, RoutedFlow, Router};
use crate::metrics::{mean, percentile};
use crate::topology::{LinkId, Topology};
use crate::util::{Json, Pcg};
use std::collections::BTreeSet;

/// Flow-pattern generator for one scenario. All patterns come from the
/// paper's evaluation: GPCNet random-ring + congestors (§3.8.2, Fig 5),
/// incast fan-ins (§3.1), permutation/ring collective rounds (§5.1),
/// uniform background traffic, lane-degraded links (§3.4) and staggered
/// arrival mixes.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Uniformly random endpoint pairs, all starting at t=0.
    UniformRandom { flows: usize, bytes: u64 },
    /// `roots` simultaneous fan-ins of `fanin` senders each.
    Incast { roots: usize, fanin: usize, bytes: u64 },
    /// GPCNet mix: random-ring victims plus (when `congestors > 0`)
    /// incast and background congestor traffic.
    GpcnetMix { victims: usize, congestors: usize, bytes: u64 },
    /// One round of a random permutation (all2all-style collective round).
    Permutation { pairs: usize, bytes: u64 },
    /// Ring neighbor exchange (one allreduce ring round).
    Ring { ranks: usize, bytes: u64 },
    /// Uniform random traffic with arrivals staggered over `window_s`.
    Staggered { flows: usize, bytes: u64, window_s: f64 },
    /// Uniform random traffic over a fabric with `link_fraction` of the
    /// used links degraded to `bw_multiplier` of nominal bandwidth
    /// (paper §3.4 lane-disable degraded mode).
    Degraded {
        flows: usize,
        bytes: u64,
        bw_multiplier: f64,
        link_fraction: f64,
    },
    /// **Closed-loop**: `rounds` dependency-released ring-collective
    /// rounds over `ranks` endpoints, with an open-loop `fanin`-wide
    /// incast congestor aimed at ring member 0's NIC (collective-vs-
    /// incast interference; `fanin = 0` is the quiet baseline).
    CollectiveIncast {
        ranks: usize,
        rounds: usize,
        bytes: u64,
        fanin: usize,
        congestor_bytes: u64,
    },
    /// **Closed-loop**: `jobs` independent ring jobs of `ranks` endpoints
    /// each, phase-staggered by `stagger_s` (multi-job phase
    /// interference).
    PhaseStaggered {
        jobs: usize,
        ranks: usize,
        rounds: usize,
        bytes: u64,
        stagger_s: f64,
    },
    /// **Closed-loop**: dependency-released ring rounds over a fabric
    /// with `link_fraction` of the used links degraded to
    /// `bw_multiplier` (§3.4 lane-disable under a collective).
    DegradedCollective {
        ranks: usize,
        rounds: usize,
        bytes: u64,
        bw_multiplier: f64,
        link_fraction: f64,
    },
    /// **Closed-loop**: one application step phase trace (HACC FFT
    /// transpose + halo, AMR-Wind halos + residual allreduces, LAMMPS
    /// halo + PPPM) as a dependency DAG (see `apps::*::step_dag`).
    AppPhase { app: PhaseApp, ranks: usize, bytes: u64 },
    /// **Closed-loop**: the multi-group application-step shape —
    /// `groups` group-aligned blocks of `ranks_per_group` endpoints run
    /// `halo_rounds` rounds of ±1 neighbour exchange (link-disjoint per
    /// group, so the DES solves the blocks as independent components),
    /// then `leader_rounds` chunked ring-allreduce rounds over the
    /// block leaders fuse the groups
    /// (`workload::halo_allreduce_rounds`). At `full_aurora()` scale —
    /// 128 x 128 = 16,384 endpoints — this is the
    /// `des_component_parallel_full_aurora` bench workload.
    HaloAllreduce {
        groups: usize,
        ranks_per_group: usize,
        halo_rounds: usize,
        bytes: u64,
        leader_rounds: usize,
        leader_bytes: u64,
    },
    /// **Open-loop service** (fabric::arrivals): `arrivals` Poisson RPC
    /// transfers at `rate`/s over `endpoints` uniformly spread NICs,
    /// with a weighted size `mix` (the entry index is the service
    /// class), streamed through the bounded-memory open-loop tier in
    /// `quantum`-second materialization windows and summarized over
    /// `window`-second metric windows ([`SteadyState`]). When
    /// `link_fraction > 0`, a deterministic fraction of the links used
    /// by a routed 256-pair sample is degraded to `bw_multiplier` of
    /// nominal bandwidth before the service starts (§3.4 degraded-mode
    /// steady state).
    OpenLoop {
        arrivals: u64,
        rate: f64,
        endpoints: usize,
        mix: Vec<RpcClass>,
        quantum: f64,
        window: f64,
        bw_multiplier: f64,
        link_fraction: f64,
    },
}

/// Which application's step trace an [`Workload::AppPhase`] scenario
/// replays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseApp {
    Hacc,
    AmrWind,
    Lammps,
}

/// One named simulation: everything needed to reproduce it bit-for-bit.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub cfg: AuroraConfig,
    pub opts: DesOpts,
    pub workload: Workload,
    /// Scenario-local seed, derived from the campaign seed and the
    /// scenario *name* — independent of position and execution order.
    pub seed: u64,
}

/// FNV-1a, used to fold scenario names into seeds (and, in
/// [`super::Campaign::chaos`], chaos-sweep cell names into fault-schedule
/// seeds).
pub(crate) fn fnv1a(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Scenario {
    pub fn new(
        name: &str,
        cfg: AuroraConfig,
        opts: DesOpts,
        workload: Workload,
        campaign_seed: u64,
    ) -> Self {
        Self {
            name: name.to_string(),
            cfg,
            opts,
            workload,
            seed: fnv1a(name) ^ campaign_seed,
        }
    }

    /// Whether this scenario runs through the open-loop *service* tier
    /// (trace/Poisson arrivals on the streaming executor with
    /// steady-state metrics) rather than a batch flow set or DAG.
    pub fn is_open_loop(&self) -> bool {
        matches!(self.workload, Workload::OpenLoop { .. })
    }

    /// Whether this scenario's workload is dependency-released (runs
    /// through [`DesSim::run_dag`] via [`Scenario::materialize_dag`])
    /// rather than open-loop timed flows.
    pub fn is_closed_loop(&self) -> bool {
        matches!(
            self.workload,
            Workload::CollectiveIncast { .. }
                | Workload::PhaseStaggered { .. }
                | Workload::DegradedCollective { .. }
                | Workload::AppPhase { .. }
                | Workload::HaloAllreduce { .. }
        )
    }

    /// Materialize a closed-loop scenario: the dependency DAG plus the
    /// (possibly degraded-link-augmented) DES options. Returns `None`
    /// for open-loop workloads (use [`Scenario::materialize`]).
    ///
    /// Fails fast: the pre-execution verifier
    /// ([`crate::fabric::analysis`]) runs over the materialized DAG and
    /// panics with the rendered report if the generator produced a
    /// structurally invalid workload — a campaign must never hand the
    /// executor a cyclic or mis-routed graph. Use [`Scenario::lint`] to
    /// get the diagnostics without the panic.
    pub fn materialize_dag(
        &self,
        topo: &Topology,
    ) -> Option<(DagWorkload, DesOpts)> {
        let out = self.materialize_dag_unchecked(topo);
        if let Some((dag, opts)) = &out {
            let analyzer = WorkloadAnalyzer::new();
            let mut rep = analyzer.analyze_dag(dag);
            // the fault timeline rides in the same options: validate it
            // with the same fail-fast posture before it reaches the heap
            if let Some(fs) = &opts.faults {
                rep.merge(analyzer.analyze_faults(fs, topo));
            }
            // ... and so does the service policy (no RPC mix on a
            // closed-loop scenario — only the knob checks apply)
            if let Some(p) = &opts.policies {
                rep.merge(analyzer.analyze_policies(p, &[], topo));
            }
            assert!(
                rep.is_clean(),
                "scenario {}: workload verifier rejected the DAG:\n{}",
                self.name,
                rep.render()
            );
        }
        out
    }

    /// The raw generator behind [`Scenario::materialize_dag`] — no
    /// verification, so [`Scenario::lint`] can report diagnostics
    /// instead of panicking.
    fn materialize_dag_unchecked(
        &self,
        topo: &Topology,
    ) -> Option<(DagWorkload, DesOpts)> {
        let mut rng = Pcg::with_stream(self.seed, 0x5ce0);
        let mut router = Router::with_seed(topo, self.seed ^ 0x707e);
        // Closed-loop scenarios re-route the same (src, dst) pairs once
        // per round; the PR-4 route cache replays the first decision (and
        // still commits load) — enabled here since PR 5. Open-loop
        // scenarios keep uncached routers (each pair routes once anyway).
        // Golden note: reproduce's golden fixture pins no campaign keys
        // (only paper-anchored scalars), so no re-pin was required; the
        // campaign makespans it *computes* shift with the cached routes
        // and get re-pinned whenever UPDATE_GOLDEN is next run on a
        // toolchain'd checkout.
        router.enable_route_cache();
        // pre-set degraded multipliers steer adaptive decisions too:
        // the router scores against *effective* bandwidth, matching the
        // DES pricing (workload-*derived* degradations below are built
        // after routing and stay post-hoc, as before)
        if !self.opts.degraded.is_empty() {
            router.set_degraded(
                self.opts.degraded.iter().map(|(l, m)| (*l, *m)),
            );
        }
        let nics_total = topo.cfg.compute_endpoints() as u64;
        let mut opts = self.opts.clone();
        match &self.workload {
            Workload::CollectiveIncast {
                ranks,
                rounds,
                bytes,
                fanin,
                congestor_bytes,
            } => {
                let nics = workload::spread_nics(topo, *ranks);
                let rr = workload::ring_rounds(&nics, *rounds, *bytes);
                let mut dag = workload::dag_from_rounds(&mut router, &rr, 0.0);
                // open-loop incast aimed at ring member 0's NIC
                let root = nics[0];
                for _ in 0..*fanin {
                    let mut src = rng.gen_range(nics_total) as u32;
                    if topo.node_of_nic(src) == topo.node_of_nic(root) {
                        src = ((src as u64 + topo.nics_per_switch() as u64)
                            % nics_total) as u32;
                    }
                    let f = Flow::new(src, root, *congestor_bytes);
                    let path = router.route(&f);
                    dag.xfer_at(RoutedFlow { flow: f, path }, 0.0);
                }
                Some((dag, opts))
            }
            Workload::PhaseStaggered {
                jobs,
                ranks,
                rounds,
                bytes,
                stagger_s,
            } => {
                let all = workload::spread_nics(topo, jobs * ranks);
                let mut b = DagBuilder::new();
                for j in 0..*jobs {
                    let nics = &all[j * ranks..(j + 1) * ranks];
                    let rr = workload::ring_rounds(nics, *rounds, *bytes);
                    workload::push_rounds(
                        &mut b,
                        &mut router,
                        &rr,
                        j as f64 * stagger_s,
                    );
                }
                Some((b.finish(), opts))
            }
            Workload::DegradedCollective {
                ranks,
                rounds,
                bytes,
                bw_multiplier,
                link_fraction,
            } => {
                let nics = workload::spread_nics(topo, *ranks);
                let rr = workload::ring_rounds(&nics, *rounds, *bytes);
                let dag = workload::dag_from_rounds(&mut router, &rr, 0.0);
                let mut links: Vec<LinkId> = dag
                    .nodes
                    .iter()
                    .filter_map(|n| match &n.kind {
                        DagKind::Xfer(rf) => Some(&rf.path.links),
                        DagKind::Compute(_) => None,
                    })
                    .flatten()
                    .copied()
                    .collect::<BTreeSet<_>>()
                    .into_iter()
                    .collect();
                rng.shuffle(&mut links);
                let k =
                    ((links.len() as f64) * link_fraction).ceil() as usize;
                for l in links.into_iter().take(k) {
                    opts.degraded.insert(l, *bw_multiplier);
                }
                Some((dag, opts))
            }
            Workload::AppPhase { app, ranks, bytes } => {
                let dag = match app {
                    PhaseApp::Hacc => crate::apps::hacc::step_dag(
                        topo, &mut router, *ranks, *bytes,
                    ),
                    PhaseApp::AmrWind => crate::apps::amr_wind::step_dag(
                        topo, &mut router, *ranks, *bytes,
                    ),
                    PhaseApp::Lammps => crate::apps::lammps::step_dag(
                        topo, &mut router, *ranks, *bytes,
                    ),
                };
                Some((dag, opts))
            }
            Workload::HaloAllreduce {
                groups,
                ranks_per_group,
                halo_rounds,
                bytes,
                leader_rounds,
                leader_bytes,
            } => {
                let blocks =
                    workload::group_blocks(topo, *groups, *ranks_per_group);
                let rounds = workload::halo_allreduce_rounds(
                    &blocks,
                    *halo_rounds,
                    *bytes,
                    *leader_rounds,
                    *leader_bytes,
                );
                Some((workload::dag_from_rounds(&mut router, &rounds, 0.0),
                      opts))
            }
            _ => None,
        }
    }

    /// Generate the routed, timed flow set plus the (possibly
    /// degraded-link-augmented) DES options for this scenario.
    /// Closed-loop workloads materialize via
    /// [`Scenario::materialize_dag`] instead and panic here.
    pub fn materialize(&self, topo: &Topology) -> (Vec<TimedFlow>, DesOpts) {
        let mut rng = Pcg::with_stream(self.seed, 0x5ce0);
        let mut router = Router::with_seed(topo, self.seed ^ 0x707e);
        // pre-set degraded multipliers steer routing (see materialize_dag)
        if !self.opts.degraded.is_empty() {
            router.set_degraded(
                self.opts.degraded.iter().map(|(l, m)| (*l, *m)),
            );
        }
        let nics = topo.cfg.compute_endpoints() as u64;
        let mut opts = self.opts.clone();
        let mut timed: Vec<TimedFlow> = Vec::new();
        let push = |router: &mut Router,
                    timed: &mut Vec<TimedFlow>,
                    f: Flow,
                    start: f64| {
            let path = router.route(&f);
            timed.push(TimedFlow { rf: RoutedFlow { path, flow: f }, start });
        };
        let rand_pair = |rng: &mut Pcg| {
            let src = rng.gen_range(nics) as u32;
            let dst =
                ((src as u64 + 1 + rng.gen_range(nics - 1)) % nics) as u32;
            (src, dst)
        };
        match &self.workload {
            Workload::UniformRandom { flows, bytes } => {
                for _ in 0..*flows {
                    let (src, dst) = rand_pair(&mut rng);
                    push(&mut router, &mut timed,
                         Flow::new(src, dst, *bytes), 0.0);
                }
            }
            Workload::Incast { roots, fanin, bytes } => {
                for _ in 0..*roots {
                    let root = rng.gen_range(nics) as u32;
                    for _ in 0..*fanin {
                        let mut src = rng.gen_range(nics) as u32;
                        if topo.node_of_nic(src) == topo.node_of_nic(root) {
                            // keep senders off the root's node so the
                            // fan-in actually crosses the fabric
                            src = ((src as u64
                                + topo.nics_per_switch() as u64)
                                % nics) as u32;
                        }
                        push(&mut router, &mut timed,
                             Flow::new(src, root, *bytes), 0.0);
                    }
                }
            }
            Workload::GpcnetMix { victims, congestors, bytes } => {
                let srcs: Vec<u32> = (0..*victims)
                    .map(|_| rng.gen_range(nics) as u32)
                    .collect();
                let perm = rng.permutation(*victims);
                for i in 0..*victims {
                    let dst = srcs[perm[i]];
                    if srcs[i] != dst {
                        push(&mut router, &mut timed,
                             Flow::new(srcs[i], dst, *bytes), 0.0);
                    }
                }
                if *congestors > 0 {
                    let roots = (*congestors / 16).max(1);
                    for _ in 0..roots {
                        let root = rng.gen_range(nics) as u32;
                        for _ in 0..12 {
                            let src = rng.gen_range(nics) as u32;
                            if src != root {
                                push(&mut router, &mut timed,
                                     Flow::new(src, root, 8 << 20), 0.0);
                            }
                        }
                    }
                    for _ in 0..*congestors {
                        let (a, b) = rand_pair(&mut rng);
                        push(&mut router, &mut timed,
                             Flow::new(a, b, 4 << 20), 0.0);
                    }
                }
            }
            Workload::Permutation { pairs, bytes } => {
                let n = (*pairs as u64).min(nics) as usize;
                let perm = rng.permutation(n);
                for (i, &p) in perm.iter().enumerate() {
                    if i != p {
                        push(&mut router, &mut timed,
                             Flow::new(i as u32, p as u32, *bytes), 0.0);
                    }
                }
            }
            Workload::Ring { ranks, bytes } => {
                let n = (*ranks as u64).min(nics) as usize;
                if n >= 2 {
                    for i in 0..n {
                        push(&mut router, &mut timed,
                             Flow::new(i as u32, ((i + 1) % n) as u32,
                                 *bytes),
                             0.0);
                    }
                }
            }
            Workload::Staggered { flows, bytes, window_s } => {
                for _ in 0..*flows {
                    let (src, dst) = rand_pair(&mut rng);
                    let start = rng.gen_f64() * *window_s;
                    push(&mut router, &mut timed,
                         Flow::new(src, dst, *bytes), start);
                }
            }
            Workload::Degraded { flows, bytes, bw_multiplier, link_fraction } => {
                for _ in 0..*flows {
                    let (src, dst) = rand_pair(&mut rng);
                    push(&mut router, &mut timed,
                         Flow::new(src, dst, *bytes), 0.0);
                }
                // degrade a deterministic fraction of the links actually
                // used (BTreeSet -> stable order before the shuffle)
                let mut links: Vec<LinkId> = timed
                    .iter()
                    .flat_map(|tf| tf.rf.path.links.iter().copied())
                    .collect::<BTreeSet<_>>()
                    .into_iter()
                    .collect();
                rng.shuffle(&mut links);
                let k = ((links.len() as f64) * link_fraction).ceil() as usize;
                for l in links.into_iter().take(k) {
                    opts.degraded.insert(l, *bw_multiplier);
                }
            }
            Workload::CollectiveIncast { .. }
            | Workload::PhaseStaggered { .. }
            | Workload::DegradedCollective { .. }
            | Workload::AppPhase { .. }
            | Workload::HaloAllreduce { .. } => unreachable!(
                "closed-loop workload '{}' materializes via materialize_dag",
                self.name
            ),
            Workload::OpenLoop { .. } => unreachable!(
                "open-loop service workload '{}' streams via run_with \
                 (fabric::arrivals::run_open_loop) and is never \
                 materialized",
                self.name
            ),
        }
        (timed, opts)
    }

    /// Execute the scenario: topology + routing + DES + summary metrics.
    /// Closed-loop scenarios run their dependency DAG through
    /// [`DesSim::run_dag`]; open-loop scenarios run timed flows through
    /// [`DesSim::run`].
    pub fn run(&self) -> ScenarioResult {
        self.run_with(&mut DesScratch::new())
    }

    /// [`Scenario::run`] over a caller-owned [`DesScratch`] — the
    /// campaign engine gives each worker one scratch reused across all
    /// the scenarios it executes. Results are identical to [`run`]'s
    /// (scratch reset is complete; the campaign determinism suite
    /// asserts it byte-for-byte).
    pub fn run_with(&self, scratch: &mut DesScratch) -> ScenarioResult {
        let topo = Topology::new(&self.cfg);
        if self.is_open_loop() {
            return self.run_service(&topo, scratch);
        }
        if let Some((dag, opts)) = self.materialize_dag(&topo) {
            // contention-free dependency-aware reference: what the
            // analytic tier predicts without queueing dynamics
            // (schema v2: its own critical_path_s field — v1 overloaded
            // rounds_upper for closed-loop rows)
            let cp = dag.critical_path_makespan(&CostModel::new(&topo));
            let res = DesSim::new(&topo, opts).run_dag_with(&dag, scratch);
            // failed/aborted transfers finish at NaN (fault injection,
            // schema v4) — they are counted in failed_flows/aborted_nodes,
            // not in the completion-time statistics
            let finishes: Vec<f64> = dag
                .xfer_ids()
                .iter()
                .map(|&i| res.node_finish[i])
                .filter(|f| f.is_finite())
                .collect();
            return ScenarioResult {
                name: self.name.clone(),
                flows: finishes.len(),
                total_bytes: dag.total_bytes(),
                makespan: res.makespan,
                mean_finish: if finishes.is_empty() {
                    0.0
                } else {
                    mean(&finishes)
                },
                p99_finish: if finishes.is_empty() {
                    0.0
                } else {
                    percentile(&finishes, 99.0)
                },
                contributors: res.contributors,
                victims: res.victims,
                rounds_upper: 0.0,
                critical_path: cp,
                steady_state: None,
                failed_flows: res.failed_flows,
                aborted_nodes: res.aborted_nodes,
                faults: self.opts.faults.clone(),
                policy: self.opts.policies.clone(),
            };
        }
        let (timed, opts) = self.materialize(&topo);
        let rounds_upper = if timed.is_empty() {
            0.0
        } else {
            CostModel::new(&topo).eval_timed(&timed, &opts.degraded).makespan
        };
        let res = DesSim::new(&topo, opts).run_with(&timed, scratch);
        // failed flows finish at NaN — excluded from the statistics,
        // surfaced in failed_flows (schema v4)
        let finishes: Vec<f64> = res
            .finish
            .iter()
            .copied()
            .filter(|f| f.is_finite())
            .collect();
        ScenarioResult {
            name: self.name.clone(),
            flows: timed.len(),
            total_bytes: timed.iter().map(|tf| tf.rf.flow.bytes).sum(),
            makespan: res.makespan,
            mean_finish: if finishes.is_empty() { 0.0 }
                         else { mean(&finishes) },
            p99_finish: if finishes.is_empty() { 0.0 }
                        else { percentile(&finishes, 99.0) },
            contributors: res.contributors,
            victims: res.victims,
            rounds_upper,
            critical_path: 0.0,
            steady_state: None,
            failed_flows: res.failed_flows,
            aborted_nodes: 0,
            faults: self.opts.faults.clone(),
            policy: self.opts.policies.clone(),
        }
    }

    /// Execute an [`Workload::OpenLoop`] service scenario: a Poisson
    /// arrival stream (seeded with the scenario's name-derived seed — no
    /// wall-clock anywhere) over the bounded-memory streaming executor,
    /// summarized as windowed steady-state metrics. The classic batch
    /// fields keep their meaning where one exists (`makespan` = last
    /// node completion, `flows` = arrivals executed) and the latency
    /// quantiles live in [`ScenarioResult::steady_state`].
    fn run_service(
        &self,
        topo: &Topology,
        scratch: &mut DesScratch,
    ) -> ScenarioResult {
        let Workload::OpenLoop {
            arrivals,
            rate,
            endpoints,
            mix,
            quantum,
            window,
            bw_multiplier,
            link_fraction,
        } = &self.workload
        else {
            unreachable!("run_service on non-service workload")
        };
        // open-loop scenarios never pass through materialize_dag, so the
        // service-policy verifier applies its fail-fast here (schema v5:
        // a campaign must never arm the executor with a NaN deadline or
        // an admission bucket that can never admit)
        if let Some(p) = &self.opts.policies {
            let rep = WorkloadAnalyzer::new().analyze_policies(p, mix, topo);
            assert!(
                rep.is_clean(),
                "scenario {}: policy verifier rejected the service \
                 policy:\n{}",
                self.name,
                rep.render()
            );
        }
        let mut rng = Pcg::with_stream(self.seed, 0x5ce0);
        let mut router = Router::with_seed(topo, self.seed ^ 0x707e);
        let eps = workload::spread_nics(topo, *endpoints);
        let mut opts = self.opts.clone();
        if *link_fraction > 0.0 {
            // Degraded steady state: sample 256 random endpoint pairs,
            // route them on a throwaway router (so the service path's
            // adaptive decisions are untouched by the sampling), and
            // degrade a deterministic fraction of the links the sample
            // used — the open-loop analogue of [`Workload::Degraded`],
            // which derives links from the materialized flow set the
            // streaming tier never holds.
            let mut probe = Router::with_seed(topo, self.seed ^ 0x707e);
            let mut seen: BTreeSet<LinkId> = BTreeSet::new();
            for _ in 0..256 {
                let s = eps[rng.gen_usize(eps.len())];
                let d = loop {
                    let d = eps[rng.gen_usize(eps.len())];
                    if d != s {
                        break d;
                    }
                };
                let f = Flow::new(s, d, 1 << 20);
                seen.extend(probe.route(&f).links.iter().copied());
            }
            let mut links: Vec<LinkId> = seen.into_iter().collect();
            rng.shuffle(&mut links);
            let k = ((links.len() as f64) * link_fraction).ceil() as usize;
            for l in links.into_iter().take(k) {
                opts.degraded.insert(l, *bw_multiplier);
            }
            router.set_degraded(
                opts.degraded.iter().map(|(l, m)| (*l, *m)),
            );
        } else if !opts.degraded.is_empty() {
            router.set_degraded(
                opts.degraded.iter().map(|(l, m)| (*l, *m)),
            );
        }
        let src = PoissonArrivals::new(
            self.seed,
            *rate,
            *arrivals,
            eps,
            mix.clone(),
        );
        let sim = DesSim::new(topo, opts);
        let (res, ss) =
            run_open_loop(&sim, scratch, src, &mut router, *quantum, *window);
        debug_assert_eq!(res.late_releases, 0, "{}: open-loop floors sit \
             inside their windows, nothing can release late", self.name);
        ScenarioResult {
            name: self.name.clone(),
            flows: res.total_nodes,
            total_bytes: ss.completed_bytes,
            makespan: res.makespan,
            mean_finish: 0.0,
            p99_finish: 0.0,
            contributors: res.contributors,
            victims: res.victims,
            rounds_upper: 0.0,
            critical_path: 0.0,
            steady_state: Some(ss),
            failed_flows: res.failed_flows,
            aborted_nodes: res.aborted_nodes,
            faults: self.opts.faults.clone(),
            policy: self.opts.policies.clone(),
        }
    }

    /// Static pre-execution analysis of this scenario's workload — the
    /// `aurorasim lint` entry point. Closed-loop scenarios analyze the
    /// fully materialized dependency DAG; open-loop service scenarios
    /// stream a bounded prefix of the arrival source (`max_rounds`
    /// quantum windows) through the round-source liveness checks; flat
    /// batch scenarios analyze the timed flow set as a dependency-free
    /// DAG. Never panics — errors come back as diagnostics in the
    /// report.
    pub fn lint(&self, topo: &Topology, max_rounds: usize) -> AnalysisReport {
        let analyzer = WorkloadAnalyzer::new();
        // the fault timeline is linted for every workload shape — it is
        // part of the scenario regardless of how the workload executes
        let mut fault_rep = AnalysisReport::default();
        if let Some(fs) = &self.opts.faults {
            fault_rep = analyzer.analyze_faults(fs, topo);
        }
        // the service policy is likewise scenario-level state: lint it
        // against the RPC mix it will govern (empty for non-service
        // workloads — only the knob checks apply there)
        if let Some(p) = &self.opts.policies {
            let mix: &[RpcClass] = match &self.workload {
                Workload::OpenLoop { mix, .. } => mix,
                _ => &[],
            };
            fault_rep.merge(analyzer.analyze_policies(p, mix, topo));
        }
        if self.is_closed_loop() {
            let (dag, _) = self
                .materialize_dag_unchecked(topo)
                .expect("closed-loop scenarios materialize a DAG");
            let mut rep = analyzer.analyze_dag(&dag);
            rep.merge(fault_rep);
            return rep;
        }
        if let Workload::OpenLoop {
            arrivals,
            rate,
            endpoints,
            mix,
            quantum,
            ..
        } = &self.workload
        {
            // the same stream construction as run_service (identical
            // seed, so the linted prefix IS the executed prefix); the
            // degraded-link sampling is skipped — it changes pricing,
            // not workload structure
            let mut router = Router::with_seed(topo, self.seed ^ 0x707e);
            let eps = workload::spread_nics(topo, *endpoints);
            let arrivals = PoissonArrivals::new(
                self.seed,
                *rate,
                *arrivals,
                eps,
                mix.clone(),
            );
            let mut src = OpenLoopSource::new(arrivals, &mut router, *quantum);
            let mut rep = analyzer.analyze_source(&mut src, max_rounds);
            rep.merge(fault_rep);
            return rep;
        }
        let (timed, _) = self.materialize(topo);
        let mut rep = analyzer.analyze_dag(&DagWorkload::from_timed(&timed));
        rep.merge(fault_rep);
        rep
    }
}

/// Summary metrics of one executed scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioResult {
    pub name: String,
    pub flows: usize,
    pub total_bytes: u64,
    pub makespan: f64,
    pub mean_finish: f64,
    pub p99_finish: f64,
    pub contributors: usize,
    pub victims: usize,
    /// Open-loop analytic reference: round-tier upper-bound makespan
    /// (all flows costed as if fully overlapping). 0 for closed-loop
    /// scenarios — their reference is [`ScenarioResult::critical_path`].
    /// (Schema v1 overloaded this field for both; v2 splits them.)
    pub rounds_upper: f64,
    /// Closed-loop analytic reference: the contention-free dependency
    /// critical path — what a dependency-aware analytic tier predicts
    /// with no queueing, so `makespan / critical_path` is the
    /// congestion-induced round slowdown only the closed-loop DES can
    /// expose. 0 for open-loop scenarios.
    pub critical_path: f64,
    /// Windowed steady-state metrics (campaign schema v3): `Some` for
    /// open-loop *service* scenarios ([`Workload::OpenLoop`]),
    /// serialized as `null` for every batch/closed-loop row.
    pub steady_state: Option<SteadyState>,
    /// Flows the fault policy gave up on (campaign schema v4): reroute
    /// with no surviving path, retry past its cap, or abort. 0 on a
    /// healthy run.
    pub failed_flows: usize,
    /// Closed-loop/stream nodes that never ran because a failed flow's
    /// dependents could not release. 0 on a healthy run and for flat
    /// batch scenarios.
    pub aborted_nodes: usize,
    /// The fault timeline this scenario priced (campaign schema v4):
    /// serialized as a `faults` block — `{policy, events}` — or `null`
    /// for fault-free scenarios.
    pub faults: Option<FaultSchedule>,
    /// The service policy this scenario armed (campaign schema v5):
    /// serialized together with the per-class degradation counters as a
    /// `degradation` block, or `null` for policy-free scenarios.
    pub policy: Option<ServicePolicy>,
}

/// Serialize one fault event for the campaign report's `faults` block
/// (schema v4). `target` is human-readable; `t_s` + `kind` are the
/// machine-stable parts.
fn fault_event_json(e: &FaultEvent) -> Json {
    let (kind, target) = match &e.kind {
        FaultKind::LinkDegrade { link, multiplier } => {
            ("link_degrade", format!("{link:?} x{multiplier}"))
        }
        FaultKind::LinkDown { link } => ("link_down", format!("{link:?}")),
        FaultKind::LinkRecover { link } => {
            ("link_recover", format!("{link:?}"))
        }
        FaultKind::NicDown { endpoint } => {
            ("nic_down", format!("nic {endpoint}"))
        }
        FaultKind::NodeDown { node } => ("node_down", format!("node {node}")),
    };
    Json::obj(vec![
        ("t_s", Json::num(e.t)),
        ("kind", Json::str(kind.to_string())),
        ("target", Json::str(target)),
    ])
}

impl ScenarioResult {
    pub fn to_json(&self) -> Json {
        let counts = |v: &Vec<u64>| {
            Json::arr(v.iter().map(|&b| Json::num(b as f64)).collect())
        };
        let steady = match &self.steady_state {
            None => Json::Null,
            Some(ss) => Json::obj(vec![
                ("arrivals", Json::num(ss.arrivals as f64)),
                ("completed", Json::num(ss.completed as f64)),
                ("duration_s", Json::num(ss.duration)),
                ("throughput_flows_per_s", Json::num(ss.throughput_flows)),
                ("throughput_bytes_per_s", Json::num(ss.throughput_bytes)),
                ("p50_s", Json::num(ss.p50)),
                ("p99_s", Json::num(ss.p99)),
                ("p999_s", Json::num(ss.p999)),
                ("max_backlog", counts(&ss.max_backlog)),
                // per-class fault-failed counts (schema v5): retired
                // from the backlog, excluded from the quantiles above
                ("failed", counts(&ss.failed)),
                ("peak_live", Json::num(ss.peak_inflight as f64)),
                ("windows", Json::num(ss.windows as f64)),
            ]),
        };
        // schema v5: per-class degradation counters, present exactly
        // when the scenario armed a service policy
        let degradation = match (&self.policy, &self.steady_state) {
            (Some(p), Some(ss)) => Json::obj(vec![
                ("policy", Json::str(p.summary())),
                ("accepted", Json::num(ss.arrivals as f64)),
                ("shed", counts(&ss.shed)),
                ("abandoned", counts(&ss.abandoned)),
                ("failed", counts(&ss.failed)),
                ("hedged", counts(&ss.hedged)),
                ("deadline_met", Json::num(ss.deadline_met as f64)),
                ("goodput_flows_per_s", Json::num(ss.goodput_flows)),
            ]),
            _ => Json::Null,
        };
        let faults = match &self.faults {
            None => Json::Null,
            Some(fs) => Json::obj(vec![
                ("policy", Json::str(fs.policy.name().to_string())),
                (
                    "events",
                    Json::arr(
                        fs.events.iter().map(fault_event_json).collect(),
                    ),
                ),
            ]),
        };
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("flows", Json::num(self.flows as f64)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("makespan_s", Json::num(self.makespan)),
            ("mean_finish_s", Json::num(self.mean_finish)),
            ("p99_finish_s", Json::num(self.p99_finish)),
            ("contributors", Json::num(self.contributors as f64)),
            ("victims", Json::num(self.victims as f64)),
            ("rounds_upper_s", Json::num(self.rounds_upper)),
            ("critical_path_s", Json::num(self.critical_path)),
            ("steady_state", steady),
            ("failed_flows", Json::num(self.failed_flows as f64)),
            ("aborted_nodes", Json::num(self.aborted_nodes as f64)),
            ("faults", faults),
            ("degradation", degradation),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> AuroraConfig {
        AuroraConfig::small(4, 4)
    }

    #[test]
    fn seeds_are_name_derived_and_order_independent() {
        let a = Scenario::new("x", small(), DesOpts::default(),
            Workload::Ring { ranks: 8, bytes: 1 << 20 }, 7);
        let b = Scenario::new("x", small(), DesOpts::default(),
            Workload::Ring { ranks: 8, bytes: 1 << 20 }, 7);
        let c = Scenario::new("y", small(), DesOpts::default(),
            Workload::Ring { ranks: 8, bytes: 1 << 20 }, 7);
        assert_eq!(a.seed, b.seed);
        assert_ne!(a.seed, c.seed);
    }

    #[test]
    fn materialize_is_deterministic() {
        let s = Scenario::new("det", small(), DesOpts::default(),
            Workload::UniformRandom { flows: 32, bytes: 1 << 20 }, 42);
        let topo = Topology::new(&s.cfg);
        let (a, _) = s.materialize(&topo);
        let (b, _) = s.materialize(&topo);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.rf.flow.src_nic, y.rf.flow.src_nic);
            assert_eq!(x.rf.flow.dst_nic, y.rf.flow.dst_nic);
            assert_eq!(x.rf.path, y.rf.path);
            assert_eq!(x.start, y.start);
        }
    }

    #[test]
    fn incast_scenario_detects_contributors() {
        let s = Scenario::new("incast", small(), DesOpts::default(),
            Workload::Incast { roots: 2, fanin: 8, bytes: 4 << 20 }, 1);
        let r = s.run();
        assert_eq!(r.flows, 16);
        assert!(r.contributors > 0, "{r:?}");
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn degraded_scenario_is_slower() {
        let base = Scenario::new("deg", small(), DesOpts::default(),
            Workload::UniformRandom { flows: 24, bytes: 4 << 20 }, 5);
        let deg = Scenario::new("deg", small(), DesOpts::default(),
            Workload::Degraded {
                flows: 24,
                bytes: 4 << 20,
                bw_multiplier: 0.25,
                link_fraction: 1.0,
            }, 5);
        // same seed + same name => same flow set; all links degraded
        let hb = base.run();
        let hd = deg.run();
        assert!(
            hd.makespan >= hb.makespan * 0.999,
            "degraded {} vs base {}",
            hd.makespan,
            hb.makespan
        );
    }

    #[test]
    fn closed_loop_congestion_slowdown_beyond_analytic_tier() {
        // acceptance: an incast congestor delays dependency-released
        // collective rounds, and the analytic (contention-free critical
        // path) tier cannot reproduce the slowdown
        let mk = |fanin| {
            Scenario::new(
                "cvi",
                small(),
                DesOpts::default(),
                Workload::CollectiveIncast {
                    ranks: 16,
                    rounds: 8,
                    bytes: 2 << 20,
                    fanin,
                    congestor_bytes: 32 << 20,
                },
                11,
            )
        };
        let topo = Topology::new(&small());
        let (dag_q, opts_q) = mk(0).materialize_dag(&topo).unwrap();
        let (dag_n, opts_n) = mk(12).materialize_dag(&topo).unwrap();
        let rq = DesSim::new(&topo, opts_q).run_dag(&dag_q);
        let rn = DesSim::new(&topo, opts_n).run_dag(&dag_n);
        // the ring nodes are the shared prefix of both DAGs
        let ring = dag_q.len();
        let last_q =
            rq.node_finish[..ring].iter().cloned().fold(0.0, f64::max);
        let last_n =
            rn.node_finish[..ring].iter().cloned().fold(0.0, f64::max);
        assert!(
            last_n > last_q * 1.3,
            "congestor must slow the rounds: quiet {last_q} noisy {last_n}"
        );
        // the analytic reference is identical for the ring in both cases
        // and far below the congested closed-loop time
        let cm = CostModel::new(&topo);
        let cp_ring = dag_q.critical_path_makespan(&cm);
        assert!(
            last_n > cp_ring * 2.0,
            "analytic critical path {cp_ring} cannot see the congestion \
             ({last_n})"
        );
        assert!(
            last_q <= cp_ring * 2.0,
            "quiet run must sit near the analytic path: {last_q} vs \
             {cp_ring}"
        );
    }

    #[test]
    fn closed_loop_scenarios_run_deterministically() {
        let cases = vec![
            Scenario::new(
                "ps",
                small(),
                DesOpts::default(),
                Workload::PhaseStaggered {
                    jobs: 2,
                    ranks: 8,
                    rounds: 4,
                    bytes: 1 << 20,
                    stagger_s: 1e-3,
                },
                5,
            ),
            Scenario::new(
                "dc",
                small(),
                DesOpts::default(),
                Workload::DegradedCollective {
                    ranks: 12,
                    rounds: 6,
                    bytes: 2 << 20,
                    bw_multiplier: 0.5,
                    link_fraction: 0.5,
                },
                5,
            ),
            Scenario::new(
                "ap",
                small(),
                DesOpts::default(),
                Workload::AppPhase {
                    app: PhaseApp::AmrWind,
                    ranks: 12,
                    bytes: 1 << 20,
                },
                5,
            ),
        ];
        for s in cases {
            assert!(s.is_closed_loop());
            let a = s.run();
            let b = s.run();
            assert_eq!(a, b, "{}", s.name);
            assert!(a.makespan > 0.0 && a.flows > 0, "{a:?}");
            assert!(a.critical_path > 0.0, "{a:?}");
            assert_eq!(
                a.rounds_upper, 0.0,
                "closed-loop rows no longer overload rounds_upper: {a:?}"
            );
        }
    }

    #[test]
    fn degraded_collective_slower_than_healthy() {
        let mk = |frac| {
            Scenario::new(
                "dcc",
                small(),
                DesOpts::default(),
                Workload::DegradedCollective {
                    ranks: 12,
                    rounds: 6,
                    bytes: 4 << 20,
                    bw_multiplier: 0.25,
                    link_fraction: frac,
                },
                7,
            )
        };
        let healthy = mk(0.0).run();
        let degraded = mk(1.0).run();
        assert!(
            degraded.makespan > healthy.makespan * 1.05,
            "degraded {} vs healthy {}",
            degraded.makespan,
            healthy.makespan
        );
    }

    fn open_loop(name: &str, arrivals: u64, frac: f64) -> Scenario {
        Scenario::new(
            name,
            small(),
            DesOpts::default(),
            Workload::OpenLoop {
                arrivals,
                rate: 50_000.0,
                endpoints: 64,
                mix: vec![
                    RpcClass { bytes: 4 << 10, weight: 0.7 },
                    RpcClass { bytes: 64 << 10, weight: 0.3 },
                ],
                quantum: 1e-3,
                window: 10e-3,
                bw_multiplier: 0.5,
                link_fraction: frac,
            },
            9,
        )
    }

    #[test]
    fn open_loop_service_scenario_reports_steady_state() {
        let s = open_loop("ol", 5_000, 0.0);
        assert!(s.is_open_loop() && !s.is_closed_loop());
        let a = s.run();
        let b = s.run();
        assert_eq!(a, b, "open-loop service runs must be deterministic");
        assert_eq!(a.flows, 5_000);
        let ss = a.steady_state.as_ref().expect("steady_state block");
        assert_eq!(ss.arrivals, 5_000);
        assert_eq!(ss.completed, 5_000);
        assert!(ss.duration > 0.0 && ss.duration.is_finite());
        assert!(ss.throughput_flows > 0.0);
        assert!(ss.p50 > 0.0 && ss.p50 <= ss.p99 && ss.p99 <= ss.p999);
        assert!(ss.peak_inflight > 0);
        assert!(!ss.max_backlog.is_empty());
        assert!(a.makespan >= ss.duration * 0.999);
        // batch rows keep null steady state (schema v3)
        let batch = Scenario::new(
            "b",
            small(),
            DesOpts::default(),
            Workload::Ring { ranks: 8, bytes: 1 << 20 },
            9,
        )
        .run();
        assert!(batch.steady_state.is_none());
    }

    #[test]
    fn open_loop_degraded_is_slower_than_healthy() {
        let h = open_loop("olh", 4_000, 0.0).run();
        let d = open_loop("olh", 4_000, 0.9).run();
        let (hs, ds) = (
            h.steady_state.as_ref().unwrap(),
            d.steady_state.as_ref().unwrap(),
        );
        assert!(
            ds.p99 >= hs.p99 * 0.999,
            "degraded p99 {} vs healthy {}",
            ds.p99,
            hs.p99
        );
    }

    #[test]
    fn staggered_window_respected() {
        let s = Scenario::new("stag", small(), DesOpts::default(),
            Workload::Staggered {
                flows: 16, bytes: 1 << 20, window_s: 0.5,
            }, 3);
        let topo = Topology::new(&s.cfg);
        let (timed, _) = s.materialize(&topo);
        assert!(timed.iter().any(|tf| tf.start > 0.0));
        assert!(timed.iter().all(|tf| (0.0..0.5).contains(&tf.start)));
    }
}
