//! Dragonfly topology (paper §3.1, Fig 2): groups of all-to-all connected
//! Rosetta switches, each switch hosting nodes with Cassini NICs, groups
//! connected all-to-all by optical global links.
//!
//! Provides the algorithmic fabric addressing of §3.6 (addresses derived
//! from topology position, no learning/broadcast), the static-ARP model of
//! §3.7, and minimal / non-minimal (Valiant) path enumeration with the
//! "at most 3 switch-to-switch hops minimal" property of §3.1.

use crate::config::AuroraConfig;

/// Directed fabric link. Bandwidth is per direction (§3.3: 200 Gbps/dir).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LinkId {
    /// NIC -> switch injection.
    NicUp(u32),
    /// switch -> NIC ejection.
    NicDown(u32),
    /// Intra-group electrical link, switch `a` -> switch `b`.
    Local { group: u16, a: u8, b: u8 },
    /// Inter-group optical link `idx`, directed `src` -> `dst` group.
    Global { src: u16, dst: u16, idx: u8 },
}

/// Algorithmic fabric address (§3.6): position-derived, enabling interval
/// routing — no MAC learning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FabricAddr {
    pub group: u16,
    pub switch: u8,
    pub port: u8,
}

/// A unidirectional route: ordered links + hop classification.
#[derive(Debug, Clone, PartialEq)]
pub struct Path {
    pub links: Vec<LinkId>,
    /// Number of switch-to-switch hops (paper: <= 3 minimal, <= 5 Valiant).
    pub switch_hops: usize,
    /// Number of optical (global) hops for propagation-delay accounting.
    pub global_hops: usize,
    pub minimal: bool,
}

/// The dragonfly graph. Everything is computed algorithmically from the
/// config — O(1) memory regardless of machine size, which is what lets the
/// analytic tier run at 84,992 endpoints.
#[derive(Debug, Clone)]
pub struct Topology {
    pub cfg: AuroraConfig,
}

impl Topology {
    pub fn new(cfg: &AuroraConfig) -> Self {
        assert!(cfg.compute_groups >= 2, "dragonfly needs >= 2 groups");
        assert!(cfg.switches_per_group >= 2);
        assert!(
            cfg.switches_per_group <= 64,
            "Rosetta is a 64-port switch (§3.2)"
        );
        Self { cfg: cfg.clone() }
    }

    // ---------------- id arithmetic ----------------

    pub fn nics_per_switch(&self) -> usize {
        self.cfg.nodes_per_switch * self.cfg.nics_per_node
    }

    pub fn nic_of_node(&self, node: usize, nic_idx: usize) -> u32 {
        debug_assert!(nic_idx < self.cfg.nics_per_node);
        (node * self.cfg.nics_per_node + nic_idx) as u32
    }

    pub fn node_of_nic(&self, nic: u32) -> usize {
        nic as usize / self.cfg.nics_per_node
    }

    pub fn switch_of_node(&self, node: usize) -> (u16, u8) {
        let sw_global = node / self.cfg.nodes_per_switch;
        (
            (sw_global / self.cfg.switches_per_group) as u16,
            (sw_global % self.cfg.switches_per_group) as u8,
        )
    }

    pub fn group_of_node(&self, node: usize) -> u16 {
        self.switch_of_node(node).0
    }

    /// Algorithmic fabric address of a NIC (§3.6).
    pub fn fabric_addr(&self, nic: u32) -> FabricAddr {
        let node = self.node_of_nic(nic);
        let (group, switch) = self.switch_of_node(node);
        let port = (node % self.cfg.nodes_per_switch) * self.cfg.nics_per_node
            + (nic as usize % self.cfg.nics_per_node);
        FabricAddr { group, switch, port: port as u8 }
    }

    /// Inverse of [`fabric_addr`] — the static-ARP resolution of §3.7:
    /// IP->MAC is a pure function of position, loaded at boot, never
    /// invalidated.
    pub fn resolve(&self, addr: FabricAddr) -> u32 {
        let node = (addr.group as usize * self.cfg.switches_per_group
            + addr.switch as usize)
            * self.cfg.nodes_per_switch
            + addr.port as usize / self.cfg.nics_per_node;
        self.nic_of_node(node, addr.port as usize % self.cfg.nics_per_node)
    }

    /// Which switch in `src` group hosts global link `idx` toward `dst`.
    /// Deterministic spread so each switch carries its share of the
    /// group's global links (Aurora: 165 peer groups x 2 links over 32
    /// switches ~ 10 global ports/switch).
    pub fn global_attach(&self, src: u16, dst: u16, idx: u8) -> u8 {
        let s = self.cfg.switches_per_group as u64;
        let h = (dst as u64)
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(idx as u64)
            .wrapping_add((src as u64).rotate_left(17));
        (h % s) as u8
    }

    // ---------------- path enumeration ----------------

    /// Minimal path for global link choice `idx` (adaptive routing scores
    /// all `global_links_compute` candidates; §3.1).
    pub fn minimal_path(&self, src_nic: u32, dst_nic: u32, idx: u8) -> Path {
        let (sg, ss) = self.switch_of_node(self.node_of_nic(src_nic));
        let (dg, ds) = self.switch_of_node(self.node_of_nic(dst_nic));
        let mut links = vec![LinkId::NicUp(src_nic)];
        let mut switch_hops = 0;
        let mut global_hops = 0;
        if sg == dg {
            if ss != ds {
                links.push(LinkId::Local { group: sg, a: ss, b: ds });
                switch_hops += 1;
            }
        } else {
            let out_sw = self.global_attach(sg, dg, idx);
            let in_sw = self.global_attach(dg, sg, idx);
            if ss != out_sw {
                links.push(LinkId::Local { group: sg, a: ss, b: out_sw });
                switch_hops += 1;
            }
            links.push(LinkId::Global { src: sg, dst: dg, idx });
            switch_hops += 1;
            global_hops += 1;
            if in_sw != ds {
                links.push(LinkId::Local { group: dg, a: in_sw, b: ds });
                switch_hops += 1;
            }
        }
        links.push(LinkId::NicDown(dst_nic));
        Path { links, switch_hops, global_hops, minimal: true }
    }

    /// Valiant non-minimal path through intermediate group `via` using
    /// global link indices `i1`, `i2`.
    pub fn nonminimal_path(
        &self,
        src_nic: u32,
        dst_nic: u32,
        via: u16,
        i1: u8,
        i2: u8,
    ) -> Path {
        let (sg, ss) = self.switch_of_node(self.node_of_nic(src_nic));
        let (dg, ds) = self.switch_of_node(self.node_of_nic(dst_nic));
        debug_assert!(via != sg && via != dg);
        let mut links = vec![LinkId::NicUp(src_nic)];
        let mut switch_hops = 0;
        // leg 1: src group -> via
        let out1 = self.global_attach(sg, via, i1);
        if ss != out1 {
            links.push(LinkId::Local { group: sg, a: ss, b: out1 });
            switch_hops += 1;
        }
        links.push(LinkId::Global { src: sg, dst: via, idx: i1 });
        switch_hops += 1;
        // transit inside via
        let in1 = self.global_attach(via, sg, i1);
        let out2 = self.global_attach(via, dg, i2);
        if in1 != out2 {
            links.push(LinkId::Local { group: via, a: in1, b: out2 });
            switch_hops += 1;
        }
        // leg 2: via -> dst group
        links.push(LinkId::Global { src: via, dst: dg, idx: i2 });
        switch_hops += 1;
        let in2 = self.global_attach(dg, via, i2);
        if in2 != ds {
            links.push(LinkId::Local { group: dg, a: in2, b: ds });
            switch_hops += 1;
        }
        links.push(LinkId::NicDown(dst_nic));
        Path { links, switch_hops, global_hops: 2, minimal: false }
    }

    /// All minimal candidates (one per parallel global link; a single
    /// candidate for intra-group).
    pub fn minimal_candidates(&self, src_nic: u32, dst_nic: u32) -> Vec<Path> {
        let sg = self.group_of_node(self.node_of_nic(src_nic));
        let dg = self.group_of_node(self.node_of_nic(dst_nic));
        let n = if sg == dg { 1 } else { self.cfg.global_links_compute };
        (0..n as u8)
            .map(|i| self.minimal_path(src_nic, dst_nic, i))
            .collect()
    }

    // ---------------- dense link ids ----------------

    /// Widest global-link bundle between any pair of groups (compute,
    /// DAOS or service) — the per-pair slot width of the dense global
    /// link-id space.
    fn max_global_links(&self) -> usize {
        self.cfg
            .global_links_compute
            .max(self.cfg.global_links_daos)
            .max(self.cfg.global_links_noncompute)
    }

    /// Size of the dense link-id space [`Topology::link_index`] mints
    /// into: every NIC injection/ejection link, every directed
    /// switch-to-switch slot and every directed global-link slot. The
    /// DES keys its per-link state by these ids, so a full-Aurora
    /// instantiation (166 compute groups, 84,992 NICs) costs one flat
    /// `u32` map of ~1.08M slots (~4.1 MiB) instead of hashing `LinkId`
    /// enums on every flow-interning step.
    pub fn link_universe(&self) -> usize {
        let e = self.cfg.compute_endpoints();
        let s = self.cfg.switches_per_group;
        let g = self.cfg.total_groups();
        2 * e + g * s * s + g * g * self.max_global_links()
    }

    /// Dense id of a directed link — a pure function of topology
    /// position (the link-level analogue of the §3.6 algorithmic fabric
    /// addresses: no learning, no hashing). Distinct links map to
    /// distinct ids below [`Topology::link_universe`].
    pub fn link_index(&self, link: &LinkId) -> u32 {
        let idx = self.link_indexer().index(link);
        debug_assert!(
            (idx as usize) < self.link_universe(),
            "link outside universe"
        );
        idx
    }

    /// Whether `link` names a real link of this topology — the
    /// non-panicking validity check behind the fault-schedule analyzer
    /// (`WorkloadAnalyzer::analyze_faults`): every id component must
    /// lie inside the range [`Topology::link_index`] mints from.
    pub fn contains_link(&self, link: &LinkId) -> bool {
        let e = self.cfg.compute_endpoints();
        let s = self.cfg.switches_per_group;
        let g = self.cfg.total_groups();
        match *link {
            LinkId::NicUp(n) | LinkId::NicDown(n) => (n as usize) < e,
            LinkId::Local { group, a, b } => {
                (group as usize) < g
                    && (a as usize) < s
                    && (b as usize) < s
                    && a != b
            }
            LinkId::Global { src, dst, idx } => {
                (src as usize) < g
                    && (dst as usize) < g
                    && src != dst
                    && (idx as usize) < self.max_global_links()
            }
        }
    }

    /// The arithmetic behind [`Topology::link_index`] as a `Copy` value:
    /// long-lived dense link-keyed stores (the router's
    /// [`crate::fabric::LoadMap`]) capture it once and mint ids without
    /// borrowing the topology.
    pub fn link_indexer(&self) -> LinkIndexer {
        LinkIndexer {
            e: self.cfg.compute_endpoints(),
            s: self.cfg.switches_per_group,
            g: self.cfg.total_groups(),
            mgl: self.max_global_links(),
        }
    }

    /// Per-direction link bandwidth.
    pub fn link_bw(&self, link: &LinkId) -> f64 {
        match link {
            LinkId::NicUp(_) | LinkId::NicDown(_) => self.cfg.nic_bw,
            LinkId::Local { .. } => self.cfg.local_link_bw,
            LinkId::Global { .. } => self.cfg.global_link_bw,
        }
    }

    /// Pure propagation + pipeline latency of a path (no queuing, no
    /// endpoint software): switch pipelines + cable flight time.
    pub fn path_latency(&self, path: &Path) -> f64 {
        let c = &self.cfg;
        let electrical_hops = path.switch_hops - path.global_hops;
        // every switch traversal costs one pipeline latency; count switches
        // visited = switch_hops + 1 (the first switch after injection).
        (path.switch_hops as f64 + 1.0) * c.switch_latency
            + electrical_hops as f64 * c.electrical_prop
            + path.global_hops as f64 * c.optical_prop
    }
}

/// Captured [`Topology::link_index`] parameters — the same injective
/// arithmetic mint, detached from the topology borrow. Obtained via
/// [`Topology::link_indexer`]; two indexers from the same topology mint
/// identical ids.
#[derive(Debug, Clone, Copy)]
pub struct LinkIndexer {
    e: usize,
    s: usize,
    g: usize,
    mgl: usize,
}

impl LinkIndexer {
    /// Size of the dense id space (== [`Topology::link_universe`]).
    pub fn universe(&self) -> usize {
        2 * self.e + self.g * self.s * self.s + self.g * self.g * self.mgl
    }

    /// Dense id of a directed link (== [`Topology::link_index`]).
    #[inline]
    pub fn index(&self, link: &LinkId) -> u32 {
        let idx = match link {
            LinkId::NicUp(n) => *n as usize,
            LinkId::NicDown(n) => self.e + *n as usize,
            LinkId::Local { group, a, b } => {
                2 * self.e
                    + (*group as usize * self.s + *a as usize) * self.s
                    + *b as usize
            }
            LinkId::Global { src, dst, idx } => {
                2 * self.e
                    + self.g * self.s * self.s
                    + (*src as usize * self.g + *dst as usize) * self.mgl
                    + *idx as usize
            }
        };
        idx as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn addr_roundtrip() {
        let t = topo();
        for nic in 0..t.cfg.compute_endpoints() as u32 {
            assert_eq!(t.resolve(t.fabric_addr(nic)), nic, "nic {nic}");
        }
    }

    #[test]
    fn minimal_at_most_three_switch_hops() {
        // paper §3.1: one source-group hop, one global, one dest-group hop
        let t = topo();
        let last = t.cfg.compute_endpoints() as u32 - 1;
        for (s, d) in [(0u32, last), (3, 40), (0, 1), (17, 90)] {
            for p in t.minimal_candidates(s, d) {
                assert!(p.switch_hops <= 3, "{s}->{d}: {}", p.switch_hops);
                assert!(p.minimal);
            }
        }
    }

    #[test]
    fn nonminimal_at_most_five_switch_hops() {
        let t = topo();
        let last = t.cfg.compute_endpoints() as u32 - 1;
        let p = t.nonminimal_path(0, last, 1, 0, 1);
        assert!(p.switch_hops <= 5);
        assert_eq!(p.global_hops, 2);
    }

    #[test]
    fn intra_group_paths_have_no_global_hop() {
        let t = topo();
        // NICs 0 and 40 share group 0 in small(4,4): 4 sw * 2 nodes * 8 nic
        let p = &t.minimal_candidates(0, 40)[0];
        assert_eq!(p.global_hops, 0);
        assert!(p.switch_hops <= 1);
    }

    #[test]
    fn paths_start_and_end_at_nics() {
        let t = topo();
        let p = t.minimal_path(5, 200, 0);
        assert_eq!(p.links.first(), Some(&LinkId::NicUp(5)));
        assert_eq!(p.links.last(), Some(&LinkId::NicDown(200)));
    }

    #[test]
    fn parallel_global_links_differ() {
        let t = topo();
        let a = t.minimal_path(0, 200, 0);
        let b = t.minimal_path(0, 200, 1);
        let ga: Vec<_> = a.links.iter()
            .filter(|l| matches!(l, LinkId::Global { .. })).collect();
        let gb: Vec<_> = b.links.iter()
            .filter(|l| matches!(l, LinkId::Global { .. })).collect();
        assert_ne!(ga, gb);
    }

    #[test]
    fn global_attach_spreads_over_switches() {
        // Aurora: 165 peers x 2 links over 32 switches ~ 10 ports/switch;
        // no switch should carry a wildly disproportionate share
        let t = Topology::new(&AuroraConfig::aurora());
        let mut count = vec![0usize; 32];
        for dst in 0..166u16 {
            for idx in 0..2u8 {
                if dst != 3 {
                    count[t.global_attach(3, dst, idx) as usize] += 1;
                }
            }
        }
        let (min, max) = (
            *count.iter().min().unwrap(),
            *count.iter().max().unwrap(),
        );
        assert!(min >= 2, "starved switch: {count:?}");
        assert!(max <= 25, "overloaded switch: {count:?}");
    }

    #[test]
    fn parallel_global_links_attach_differently_somewhere() {
        // the two parallel links between a group pair should not always
        // land on the same switch (they'd share fate otherwise)
        let t = Topology::new(&AuroraConfig::aurora());
        let differing = (0..166u16)
            .filter(|&dst| dst != 0)
            .filter(|&dst| {
                t.global_attach(0, dst, 0) != t.global_attach(0, dst, 1)
            })
            .count();
        assert!(differing > 140, "only {differing}/165 pairs split");
    }

    #[test]
    fn link_index_is_injective_and_bounded() {
        // every link a routed path can produce must mint a distinct id
        // below the universe — sweep all NIC links plus every local and
        // (sampled) global slot of a small machine
        let t = topo();
        let mut seen = std::collections::HashSet::new();
        let uni = t.link_universe();
        let mut check = |l: LinkId| {
            let id = t.link_index(&l);
            assert!((id as usize) < uni, "{l:?} -> {id} >= {uni}");
            assert!(seen.insert(id), "duplicate id {id} for {l:?}");
        };
        for n in 0..t.cfg.compute_endpoints() as u32 {
            check(LinkId::NicUp(n));
            check(LinkId::NicDown(n));
        }
        let s = t.cfg.switches_per_group as u8;
        for g in 0..t.cfg.total_groups() as u16 {
            for a in 0..s {
                for b in 0..s {
                    check(LinkId::Local { group: g, a, b });
                }
            }
        }
        for src in 0..t.cfg.total_groups() as u16 {
            for dst in 0..t.cfg.total_groups() as u16 {
                for idx in 0..t.cfg.global_links_compute as u8 {
                    check(LinkId::Global { src, dst, idx });
                }
            }
        }
    }

    #[test]
    fn link_index_covers_full_aurora_paths() {
        // full-machine minimal + Valiant paths index inside the universe
        let t = Topology::new(&AuroraConfig::aurora());
        let uni = t.link_universe();
        let last = t.cfg.compute_endpoints() as u32 - 1;
        let mut paths = t.minimal_candidates(0, last);
        paths.push(t.nonminimal_path(0, last, 7, 0, 1));
        paths.push(t.minimal_path(3, 40, 0));
        for p in &paths {
            for l in &p.links {
                assert!((t.link_index(l) as usize) < uni, "{l:?}");
            }
        }
    }

    #[test]
    fn latency_scales_with_hops() {
        let t = topo();
        let near = t.minimal_path(0, 16, 0); // same switch region
        let far = t.minimal_path(0, t.cfg.compute_endpoints() as u32 - 1, 0);
        assert!(t.path_latency(&far) > t.path_latency(&near));
    }
}
