//! # AuroraSim
//!
//! Full-stack simulation of the Aurora exascale system reproducing
//! *Scaling MPI Applications on Aurora* (CS.DC 2025): a parametric
//! Slingshot-11 dragonfly fabric (Rosetta switches + Cassini NICs), an
//! Aurora node model (2x SPR-HBM + 6x PVC + 8 NICs), an MPI runtime with
//! the paper's collective/RMA behaviours, the HPE fabric-manager control
//! plane, the fabric-validation methodology of paper §3.8, and every
//! benchmark/application of paper §5 as a workload over the simulated
//! machine.
//!
//! Layering (see DESIGN.md):
//! * L3 (this crate) owns topology, routing, congestion, QoS, MPI, the
//!   launcher and the reproduction harness.
//! * L2/L1 (JAX + Pallas, build time only) provide the per-rank compute
//!   graphs as AOT HLO artifacts executed through [`runtime`] (PJRT CPU).
//!
//! Quick start:
//! ```no_run
//! use aurorasim::config::AuroraConfig;
//! use aurorasim::machine::Machine;
//!
//! let cfg = AuroraConfig::aurora();       // the paper's 10,624-node system
//! let machine = Machine::new(&cfg);
//! println!("{}", machine.spec_table());   // paper Table 1
//! ```
//!
//! Campaign runner (parallel multi-scenario sweeps with deterministic,
//! byte-stable JSON reports — see [`campaign`]):
//! ```no_run
//! use aurorasim::campaign::{pool, Campaign};
//! use aurorasim::config::AuroraConfig;
//!
//! let c = Campaign::standard(&AuroraConfig::small(8, 4), 0xA112a);
//! let report = c.run(pool::default_threads());
//! println!("{}", report.render_table());
//! report.write("campaign.json").unwrap();
//! ```
//! The same suite is reachable as `repro campaign [threads] [out.json]`
//! from the CLI and as experiment id `campaign` in `repro reproduce`.

pub mod apps;
pub mod campaign;
pub mod config;
pub mod coordinator;
pub mod fabric;
pub mod fabricmgr;
pub mod machine;
pub mod metrics;
pub mod mpi;
pub mod node;
pub mod reproduce;
pub mod runtime;
pub mod topology;
pub mod util;
pub mod validate;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Bytes.
pub type Bytes = u64;
