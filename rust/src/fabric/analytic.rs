//! Closed-form link-load analysis for uniform patterns at full-machine
//! scale (84,992 endpoints) — the tier behind Fig 4 and the Table 1
//! aggregate numbers.
//!
//! For a uniform all2all the per-link loads are exactly computable: each
//! byte crosses the group bisection with probability ~1/2, every group
//! pair carries 1/G^2 of the traffic, and the in-node limits (PPN x
//! per-rank issue rate, NIC count x effective bandwidth, per-NIC message
//! rate) bound injection. Adaptive routing does not achieve the
//! theoretical bisection: the paper's own 9,658-node measurement (228.92
//! TB/s aggregate, Fig 4) calibrates the routing/software efficiency
//! constant [`ALLTOALL_ROUTING_EFF`].

use crate::config::AuroraConfig;

/// Fraction of the theoretical bisection bound a real adaptive-routed
/// all2all achieves (calibrated from Fig 4: 228.92 TB/s at 9,658 nodes,
/// PPN 16 => 23.7 GB/s/node vs ~71 GB/s/node bisection share).
pub const ALLTOALL_ROUTING_EFF: f64 = 0.365;

/// Per-message software pipeline cost inside an all2all exchange phase
/// (pairwise-exchange progress engine, not the wire latency).
pub const ALLTOALL_MSG_COST: f64 = 1.9e-6;

/// Aggregate all2all bandwidth (bytes/s, summed over all ranks — the
/// quantity Fig 4 plots) for `nodes` nodes x `ppn` ranks sending
/// `msg_bytes` to every other rank.
pub fn alltoall_aggregate_bw(
    cfg: &AuroraConfig,
    nodes: usize,
    ppn: usize,
    msg_bytes: u64,
) -> f64 {
    assert!(nodes >= 2);
    let s = msg_bytes as f64;
    // --- per-rank issue pipeline: message cost + serialization ---
    let per_rank = s / (ALLTOALL_MSG_COST + s / cfg.rank_issue_bw_host);
    // --- per-node ceilings ---
    let nic_limit = cfg.nics_per_node as f64 * cfg.nic_eff_bw_host;
    let msg_rate_limit =
        cfg.nics_per_node as f64 * cfg.nic_msg_rate * s;
    // --- fabric ceiling: bisection share with routing efficiency ---
    let total_nodes = cfg.nodes() as f64;
    let frac = nodes as f64 / total_nodes;
    // bisection available to the job scales with its footprint
    let bisect_share =
        cfg.global_bisection_bw() * frac.min(1.0) * ALLTOALL_ROUTING_EFF;
    let fabric_per_node = bisect_share / nodes as f64;
    let per_node = (ppn as f64 * per_rank)
        .min(nic_limit)
        .min(msg_rate_limit)
        .min(fabric_per_node);
    per_node * nodes as f64
}

/// Theoretical (no-routing-tax) all2all upper bound — used by the ablation
/// bench to show how far adaptive routing sits from the wire limit.
pub fn alltoall_theoretical_bw(cfg: &AuroraConfig, nodes: usize) -> f64 {
    let frac = nodes as f64 / cfg.nodes() as f64;
    (cfg.global_bisection_bw() * frac.min(1.0))
        .min(nodes as f64 * cfg.nics_per_node as f64 * cfg.nic_eff_bw_host)
}

/// Aggregate uni-directional bandwidth of `pairs` simultaneous pairwise
/// streams (osu_mbw_mr shape, Fig 6/7): every node pairs with a node in
/// the "other half", `ppn` ranks per node round-robined over the NICs.
pub fn mbw_mr_aggregate(
    cfg: &AuroraConfig,
    nodes: usize,
    ppn: usize,
    msg_bytes: u64,
) -> f64 {
    assert!(nodes >= 2 && nodes % 2 == 0);
    let s = msg_bytes as f64;
    // ranks per NIC on the sender side
    let ranks_per_nic =
        (ppn as f64 / cfg.nics_per_node as f64).max(1.0 / 8.0);
    // one rank per NIC cannot saturate it (Fig 11); aggregate per NIC is
    // min(sum of rank issue rates, NIC effective bw)
    let per_rank = s / (cfg.mpi_overhead + s / cfg.rank_issue_bw_host);
    let per_nic = (ranks_per_nic * per_rank).min(cfg.nic_eff_bw_host);
    let nics_used = (ppn.min(cfg.nics_per_node)) as f64;
    let per_node = (per_nic * nics_used)
        .min(ppn as f64 * per_rank);
    // half the nodes send
    per_node * (nodes / 2) as f64
}

/// Natural-ring neighbour-exchange per-rank bandwidth (GPCNet pattern):
/// neighbours are placement-adjacent so traffic stays intra-group.
pub fn natural_ring_bw(cfg: &AuroraConfig, msg_bytes: u64) -> f64 {
    let s = msg_bytes as f64;
    // two concurrent directions share the rank's NIC slice
    (s / (cfg.mpi_overhead + s / cfg.rank_issue_bw_host))
        .min(cfg.nic_eff_bw_host / 2.0)
}

/// Random-ring per-rank bandwidth: partners are uniformly remote, so the
/// stream crosses global links shared (on average) with the other random
/// pairs mapped to the same group pair.
pub fn random_ring_bw(cfg: &AuroraConfig, nodes: usize, ppn: usize,
                      msg_bytes: u64) -> f64 {
    let s = msg_bytes as f64;
    let per_rank = s / (cfg.mpi_overhead + s / cfg.rank_issue_bw_host);
    // expected global-link sharing: ranks per group / links per group pair
    let groups = ((nodes + cfg.switches_per_group * cfg.nodes_per_switch - 1)
        / (cfg.switches_per_group * cfg.nodes_per_switch))
        .max(1);
    let ranks_per_group = (nodes * ppn) as f64 / groups as f64;
    let global_links_out = (groups.saturating_sub(1).max(1)
        * cfg.global_links_compute) as f64;
    let per_rank_global_share =
        cfg.global_link_bw * global_links_out / ranks_per_group;
    // random ring is also bidirectional, so the natural-ring NIC budget
    // is an upper bound
    per_rank
        .min(per_rank_global_share)
        .min(natural_ring_bw(cfg, msg_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_peak_aggregate_matches_paper() {
        // 9,658 nodes x PPN 16, large messages: paper reports 228.92 TB/s
        let cfg = AuroraConfig::aurora();
        let bw = alltoall_aggregate_bw(&cfg, 9658, 16, 1 << 20);
        let tb = bw / 1e12;
        assert!(
            (tb - 228.92).abs() / 228.92 < 0.10,
            "all2all peak {tb} TB/s vs paper 228.92"
        );
    }

    #[test]
    fn alltoall_rises_with_size_and_saturates() {
        let cfg = AuroraConfig::aurora();
        let sizes = [64u64, 1024, 16 << 10, 256 << 10, 4 << 20];
        let bws: Vec<f64> = sizes
            .iter()
            .map(|s| alltoall_aggregate_bw(&cfg, 9658, 16, *s))
            .collect();
        for w in bws.windows(2) {
            assert!(w[1] >= w[0] * 0.999, "non-monotone: {bws:?}");
        }
        // tiny messages must be far below peak (latency/rate bound)
        assert!(bws[0] < bws[4] * 0.2);
    }

    #[test]
    fn alltoall_below_theoretical() {
        let cfg = AuroraConfig::aurora();
        for nodes in [256, 1024, 9658] {
            let real = alltoall_aggregate_bw(&cfg, nodes, 16, 4 << 20);
            let theory = alltoall_theoretical_bw(&cfg, nodes);
            assert!(real < theory, "{nodes} nodes: {real} !< {theory}");
        }
    }

    #[test]
    fn mbw_mr_scales_with_ppn_until_nic_saturation() {
        // Fig 7 shape: PPN 1 -> 8 grows, saturating at the NIC limit
        let cfg = AuroraConfig::aurora();
        let big = 1 << 20;
        let bw1 = mbw_mr_aggregate(&cfg, 128, 1, big);
        let bw4 = mbw_mr_aggregate(&cfg, 128, 4, big);
        let bw8 = mbw_mr_aggregate(&cfg, 128, 8, big);
        let bw16 = mbw_mr_aggregate(&cfg, 128, 16, big);
        assert!(bw4 > bw1 * 3.0);
        assert!(bw8 > bw4 * 1.5);
        // beyond 8 the ranks share NICs: growth continues (a second rank
        // per NIC saturates it — §5.1/Fig 11) but is sublinear
        assert!(bw16 > bw8, "second rank per NIC must add bandwidth");
        assert!(bw16 < bw8 * 2.0, "NIC-shared regime must be sublinear");
    }

    #[test]
    fn fig6_scale_aggregate() {
        // 10,262 nodes, PPN 8, large messages: should be in the same
        // regime as the paper's osu_mbw_mr validation (per-node ~ 8 NICs
        // at one rank each, not saturated => ~ 8 x 12 GB/s-ish)
        let cfg = AuroraConfig::aurora();
        let bw = mbw_mr_aggregate(&cfg, 10262, 8, 1 << 20);
        let per_sending_node = bw / (10262.0 / 2.0);
        assert!(
            per_sending_node > 60e9 && per_sending_node < 200e9,
            "per-node {per_sending_node}"
        );
    }

    #[test]
    fn random_ring_below_natural_ring() {
        // GPCNet: random ring crosses global links => lower bw/rank
        let cfg = AuroraConfig::aurora();
        let nat = natural_ring_bw(&cfg, 128 << 10);
        let rnd = random_ring_bw(&cfg, 9658, 8, 128 << 10);
        assert!(rnd <= nat, "random {rnd} natural {nat}");
    }
}
