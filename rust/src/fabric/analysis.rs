//! Pre-execution workload static analysis (the paper's fabric-validation
//! posture applied to *workloads*: check invariants before running at
//! scale, §3.8).
//!
//! The executors enforce most structural invariants only as they trip
//! over them — a forward dependency panics inside
//! [`DagWorkload::push`], an aliased endpoint asserts inside
//! [`super::workload::spread_nics`], a malformed round deadlocks the
//! frontier mid-run. [`WorkloadAnalyzer`] front-loads those checks into
//! one pass that produces a structured [`AnalysisReport`] — diagnostics
//! with severity, node and round ids — over any [`DagWorkload`] or a
//! materialized [`RoundSource`] prefix, *before* any solve runs:
//!
//! * cycle-freeness and dependency sanity: iterative Kahn walk (no
//!   recursion — 16k-rank DAGs must not blow the stack), dangling and
//!   forward dependency ids;
//! * release-floor sanity: non-finite floors are errors, negative
//!   floors warnings (the executor clamps them to 0);
//! * NIC aliasing, generalizing the `spread_nics` assert: self-flows
//!   (src == dst) and unrouted (empty-path) transfers are errors,
//!   inconsistent key→NIC bindings warnings;
//! * `NO_KEY` sentinel misuse: a half-sentinel stream node (`a` is
//!   [`NO_KEY`] but `b` is not, or vice versa) would thread the
//!   sentinel through the frontier as a real key — giving a "no
//!   dependencies" node dependents and breaking streamed/staged
//!   equivalence — so it is an error;
//! * key liveness: a frontier key re-touched after a long idle gap is
//!   the sparse-key memory class from PR 4 (pre-collapse it pinned
//!   every round since the last touch live) — flagged as a warning
//!   with the gap;
//! * round-source liveness ([`WorkloadAnalyzer::analyze_source`]): a
//!   time-throttled source that emits an *empty* round defeats the
//!   executor's `EV_ROUND` throttling (the skip loop re-pulls
//!   immediately, so an always-empty source spins forever) — an
//!   error, as is a non-monotone `next_round_not_before`;
//! * byte conservation for the collective round generators
//!   ([`check_collective_rounds`]): the ring allreduce must move
//!   exactly `2*(P-1)*max(bytes/P, 1)` bytes per rank, the pairwise
//!   all2all every ordered pair exactly once, and so on — checked
//!   against `mpi::coll::*_rounds` output by `tests/analysis.rs`;
//! * fault timelines ([`WorkloadAnalyzer::analyze_faults`]): fire
//!   times finite and non-decreasing, link/endpoint/node ids present
//!   in the topology, degrade multipliers in (0.0, 1.0], recoveries
//!   anchored to a prior down — validated before a
//!   [`super::faults::FaultSchedule`] reaches the event heap;
//! * service policies ([`WorkloadAnalyzer::analyze_policies`]):
//!   deadlines, hedge delays, retry budgets and admission knobs must
//!   be finite-or-infinite and non-negative, and a deadline shorter
//!   than its class's *uncongested* critical path (bytes over the
//!   best-case endpoint bandwidth) can never be met — validated
//!   before a [`super::degrade::ServicePolicy`] arms the executor.
//!
//! Wiring: `Scenario::materialize_dag` fails fast on an invalid
//! workload, the `aurorasim lint [scenario|--all]` CLI verb sweeps
//! every campaign scenario, and `debug_assertions` builds self-check
//! every `run_dag`/`run_stream` entry (`des.rs`), so the whole test
//! suite exercises the verifier for free.

use super::arrivals::RpcClass;
use super::degrade::ServicePolicy;
use super::faults::{FaultKind, FaultSchedule};
use super::workload::{DagKind, DagWorkload, RoundSource, StreamNode, NO_KEY};
use crate::topology::{LinkId, Topology};
use rustc_hash::{FxHashMap, FxHashSet};

/// How bad a finding is. `Error` means the workload violates an
/// executor contract and must not run; `Warning` flags legal but
/// suspicious structure; `Info` is advisory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

/// One finding: which check fired, where (node id in the workload /
/// emission order, round index for streamed prefixes), and why.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable check id (`cycle`, `forward-dep`, `self-flow`, ...).
    pub check: &'static str,
    pub node: Option<u32>,
    pub round: Option<u32>,
    pub message: String,
}

/// Structured result of one analysis pass.
#[derive(Debug, Clone, Default)]
pub struct AnalysisReport {
    pub diags: Vec<Diagnostic>,
    /// Nodes examined (DAG nodes or streamed nodes).
    pub nodes: usize,
    /// Rounds examined (0 for flat DAG analysis).
    pub rounds: usize,
}

impl AnalysisReport {
    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags
            .iter()
            .filter(|d| d.severity == Severity::Warning)
            .count()
    }

    /// No errors (warnings and infos are allowed).
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    /// Fold another report's diagnostics and counters into this one —
    /// used by `Scenario::lint` to combine the workload pass with the
    /// fault-schedule pass into one report.
    pub fn merge(&mut self, other: AnalysisReport) {
        self.diags.extend(other.diags);
        self.nodes += other.nodes;
        self.rounds += other.rounds;
    }

    fn push(
        &mut self,
        severity: Severity,
        check: &'static str,
        node: Option<u32>,
        round: Option<u32>,
        message: String,
    ) {
        self.diags.push(Diagnostic { severity, check, node, round, message });
    }

    /// Human-readable rendering, one line per diagnostic plus a summary.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for d in &self.diags {
            let sev = match d.severity {
                Severity::Error => "error",
                Severity::Warning => "warning",
                Severity::Info => "info",
            };
            let _ = write!(out, "{sev}[{}]", d.check);
            if let Some(n) = d.node {
                let _ = write!(out, " node {n}");
            }
            if let Some(r) = d.round {
                let _ = write!(out, " round {r}");
            }
            let _ = writeln!(out, ": {}", d.message);
        }
        let _ = write!(
            out,
            "{} nodes, {} rounds: {} error(s), {} warning(s)",
            self.nodes,
            self.rounds,
            self.errors(),
            self.warnings()
        );
        out
    }
}

/// The pre-execution workload verifier. Stateless apart from
/// thresholds; one instance can analyze any number of workloads.
#[derive(Debug, Clone)]
pub struct WorkloadAnalyzer {
    /// A frontier key idle for more than this many rounds before being
    /// re-touched gets a `sparse-key` warning (the PR 4 memory class:
    /// without done-floor collapse such a key pins every round since
    /// its last touch live).
    pub sparse_key_gap: u32,
}

impl Default for WorkloadAnalyzer {
    fn default() -> Self {
        Self { sparse_key_gap: 4096 }
    }
}

impl WorkloadAnalyzer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Analyze a fully materialized dependency workload.
    pub fn analyze_dag(&self, wl: &DagWorkload) -> AnalysisReport {
        let mut rep = AnalysisReport {
            nodes: wl.nodes.len(),
            ..Default::default()
        };
        let n = wl.nodes.len();

        // ---- dependency-id sanity + per-node local checks ----
        let mut edges = 0usize;
        for (ni, node) in wl.nodes.iter().enumerate() {
            let id = ni as u32;
            for &d in &node.deps {
                edges += 1;
                if d as usize >= n {
                    rep.push(
                        Severity::Error,
                        "dangling-dep",
                        Some(id),
                        None,
                        format!("dependency {d} beyond the last node ({n})"),
                    );
                } else if d >= id {
                    rep.push(
                        Severity::Error,
                        "forward-dep",
                        Some(id),
                        None,
                        format!(
                            "dependency {d} not before node {id} (nodes \
                             must be added in topological order)"
                        ),
                    );
                }
            }
            self.check_floor(&mut rep, node.start, Some(id), None);
            if let DagKind::Xfer(rf) = &node.kind {
                self.check_xfer(
                    &mut rep,
                    rf.flow.src_nic,
                    rf.flow.dst_nic,
                    rf.flow.bytes,
                    rf.path.links.len(),
                    Some(id),
                    None,
                );
            }
        }

        // ---- cycle-freeness: iterative Kahn peel (never recursive —
        // a 16k-rank app step is ~100k nodes deep in the worst case).
        // With the forward-dep contract intact a cycle is impossible;
        // this catches direct `nodes` manipulation that bypassed
        // `DagWorkload::push`. Dangling deps are skipped here (already
        // reported) so the walk stays in-bounds. ----
        let mut indeg = vec![0u32; n];
        let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (ni, node) in wl.nodes.iter().enumerate() {
            for &d in &node.deps {
                if (d as usize) < n {
                    indeg[ni] += 1;
                    succs[d as usize].push(ni as u32);
                }
            }
        }
        let mut queue: Vec<u32> = (0..n as u32)
            .filter(|&i| indeg[i as usize] == 0)
            .collect();
        let mut peeled = 0usize;
        while let Some(i) = queue.pop() {
            peeled += 1;
            for &s in &succs[i as usize] {
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    queue.push(s);
                }
            }
        }
        if peeled < n {
            let member = indeg
                .iter()
                .position(|&d| d > 0)
                .map(|i| i as u32)
                .unwrap_or(0);
            rep.push(
                Severity::Error,
                "cycle",
                Some(member),
                None,
                format!(
                    "{} node(s) unreachable by the Kahn peel (dependency \
                     cycle; first member: node {member})",
                    n - peeled
                ),
            );
        }
        let _ = edges;
        rep
    }

    /// Analyze a materialized list of streamed rounds (the frontier-key
    /// semantics of [`super::workload::DagBuilder`] /
    /// [`super::des::DesSim::run_stream`]).
    pub fn analyze_rounds(&self, rounds: &[Vec<StreamNode>]) -> AnalysisReport {
        let mut rep = AnalysisReport::default();
        self.rounds_pass(&mut rep, rounds, false);
        rep
    }

    /// Materialize up to `max_rounds` rounds from a live source and
    /// analyze the prefix, additionally enforcing the [`RoundSource`]
    /// contract itself: `next_round_not_before` must be non-decreasing,
    /// and a time-throttled source must never emit an *empty* round —
    /// the executor's skip loop would immediately re-pull, defeating
    /// the throttle (and spinning forever on an infinite empty tail),
    /// which is the deadlock-freedom guarantee the open-loop tier
    /// relies on. Consumes the prefix; pass a freshly built source.
    pub fn analyze_source(
        &self,
        src: &mut dyn RoundSource,
        max_rounds: usize,
    ) -> AnalysisReport {
        let mut rep = AnalysisReport::default();
        let mut rounds: Vec<Vec<StreamNode>> = Vec::new();
        let mut last_nb = f64::NEG_INFINITY;
        while rounds.len() < max_rounds {
            let nb = src.next_round_not_before();
            if !nb.is_finite() {
                rep.push(
                    Severity::Error,
                    "bad-not-before",
                    None,
                    Some(rounds.len() as u32),
                    format!("next_round_not_before returned {nb}"),
                );
                break;
            }
            // an exhausted open-loop source reports 0.0 ("no deferral");
            // only flag a regression between two *pulled* rounds
            if nb < last_nb && nb != 0.0 {
                rep.push(
                    Severity::Error,
                    "non-monotone-not-before",
                    None,
                    Some(rounds.len() as u32),
                    format!(
                        "next_round_not_before went backwards: {nb} after \
                         {last_nb}"
                    ),
                );
            }
            last_nb = last_nb.max(nb);
            let Some(round) = src.next_round() else { break };
            if round.is_empty() {
                rep.push(
                    Severity::Error,
                    "empty-round",
                    None,
                    Some(rounds.len() as u32),
                    "time-throttled source emitted an empty round (the \
                     executor re-pulls immediately: throttle defeated, \
                     potential spin on an empty tail) — advance \
                     next_round_not_before instead"
                        .into(),
                );
            }
            for n in &round {
                let floor = match n {
                    StreamNode::Compute { start, .. }
                    | StreamNode::Xfer { start, .. } => *start,
                };
                if floor.is_finite() && floor < nb {
                    rep.push(
                        Severity::Warning,
                        "floor-below-window",
                        None,
                        Some(rounds.len() as u32),
                        format!(
                            "release floor {floor} below the declared \
                             window start {nb} (would clamp as a late \
                             release)"
                        ),
                    );
                }
            }
            rounds.push(round);
        }
        self.rounds_pass(&mut rep, &rounds, true);
        rep
    }

    /// The shared per-round checks (`analyze_rounds` on a materialized
    /// list, or the prefix collected by [`Self::analyze_source`]).
    fn rounds_pass(
        &self,
        rep: &mut AnalysisReport,
        rounds: &[Vec<StreamNode>],
        from_source: bool,
    ) {
        rep.rounds += rounds.len();
        // key -> (last round touched, NIC binding) — both sides of the
        // spread_nics generalization live here
        let mut key_last: FxHashMap<u32, u32> = FxHashMap::default();
        let mut key_nic: FxHashMap<u32, u32> = FxHashMap::default();
        let mut key_floor: FxHashMap<u32, f64> = FxHashMap::default();
        let mut staged_floor: FxHashMap<u32, f64> = FxHashMap::default();
        let mut last_no_key_floor = f64::NEG_INFINITY;
        let mut id = 0u32;
        for (k, round) in rounds.iter().enumerate() {
            let rk = k as u32;
            if round.is_empty() && !from_source {
                // the executor skips these; only a *throttled* source
                // emitting them is a liveness hazard (handled above)
                rep.push(
                    Severity::Warning,
                    "empty-round",
                    None,
                    Some(rk),
                    "empty round (skipped by the executor)".into(),
                );
            }
            staged_floor.clear();
            for n in round {
                rep.nodes += 1;
                let (a, b, floor) = match n {
                    StreamNode::Compute { a, b, start, .. } => (*a, *b, *start),
                    StreamNode::Xfer { a, b, rf, start } => {
                        self.check_xfer(
                            rep,
                            rf.flow.src_nic,
                            rf.flow.dst_nic,
                            rf.flow.bytes,
                            rf.path.links.len(),
                            Some(id),
                            Some(rk),
                        );
                        // key -> NIC binding consistency (a logical
                        // endpoint aliased onto two NICs is the class
                        // spread_nics asserts against)
                        if *a != NO_KEY {
                            self.check_binding(
                                rep, &mut key_nic, *a, rf.flow.src_nic, id, rk,
                            );
                        }
                        if *b != NO_KEY {
                            self.check_binding(
                                rep, &mut key_nic, *b, rf.flow.dst_nic, id, rk,
                            );
                        }
                        (*a, *b, *start)
                    }
                };
                self.check_floor(rep, floor, Some(id), Some(rk));
                if (a == NO_KEY) != (b == NO_KEY) {
                    rep.push(
                        Severity::Error,
                        "no-key-misuse",
                        Some(id),
                        Some(rk),
                        format!(
                            "half-sentinel keys ({a}, {b}): NO_KEY must \
                             cover both ends or neither — a half-sentinel \
                             registers the sentinel in the frontier and \
                             gives a floor-released node dependents"
                        ),
                    );
                }
                if a == NO_KEY && b == NO_KEY {
                    // open-loop arrivals: floors are the schedule and
                    // must be non-decreasing in emission order
                    if floor.is_finite() && floor < last_no_key_floor {
                        rep.push(
                            Severity::Warning,
                            "no-key-floor-regression",
                            Some(id),
                            Some(rk),
                            format!(
                                "NO_KEY floor {floor} before previous \
                                 {last_no_key_floor} (arrival order \
                                 contract)"
                            ),
                        );
                    }
                    last_no_key_floor = last_no_key_floor.max(floor);
                } else {
                    for key in [a, b] {
                        if key == NO_KEY {
                            continue;
                        }
                        if let Some(&last) = key_last.get(&key) {
                            let gap = rk - last;
                            if gap > self.sparse_key_gap {
                                rep.push(
                                    Severity::Warning,
                                    "sparse-key",
                                    Some(id),
                                    Some(rk),
                                    format!(
                                        "key {key} idle for {gap} rounds \
                                         (> {}): the sparse-key memory \
                                         class (PR 4)",
                                        self.sparse_key_gap
                                    ),
                                );
                            }
                        }
                        // floors per key must not regress across rounds
                        // (per-rank clocks only move forward)
                        if floor > 0.0 {
                            if let Some(&prev) = key_floor.get(&key) {
                                if floor < prev {
                                    rep.push(
                                        Severity::Warning,
                                        "floor-regression",
                                        Some(id),
                                        Some(rk),
                                        format!(
                                            "key {key} floor {floor} below \
                                             its previous round floor \
                                             {prev}"
                                        ),
                                    );
                                }
                            }
                            let e =
                                staged_floor.entry(key).or_insert(floor);
                            *e = e.max(floor);
                        }
                    }
                }
                id += 1;
            }
            // commit this round's touches after the round (within a
            // round all nodes see the pre-round frontier)
            for n in round {
                let (a, b) = match n {
                    StreamNode::Compute { a, b, .. } => (*a, *b),
                    StreamNode::Xfer { a, b, .. } => (*a, *b),
                };
                for key in [a, b] {
                    if key != NO_KEY {
                        key_last.insert(key, rk);
                    }
                }
            }
            for (&key, &fl) in &staged_floor {
                let e = key_floor.entry(key).or_insert(fl);
                *e = e.max(fl);
            }
        }
    }

    fn check_floor(
        &self,
        rep: &mut AnalysisReport,
        start: f64,
        node: Option<u32>,
        round: Option<u32>,
    ) {
        if !start.is_finite() {
            rep.push(
                Severity::Error,
                "bad-floor",
                node,
                round,
                format!("non-finite release floor {start}"),
            );
        } else if start < 0.0 {
            rep.push(
                Severity::Warning,
                "negative-floor",
                node,
                round,
                format!("negative release floor {start} (clamped to 0)"),
            );
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn check_xfer(
        &self,
        rep: &mut AnalysisReport,
        src: u32,
        dst: u32,
        bytes: u64,
        path_links: usize,
        node: Option<u32>,
        round: Option<u32>,
    ) {
        if src == dst {
            rep.push(
                Severity::Error,
                "self-flow",
                node,
                round,
                format!(
                    "transfer from NIC {src} to itself (aliased \
                     endpoints; see spread_nics)"
                ),
            );
        }
        if path_links == 0 {
            rep.push(
                Severity::Error,
                "empty-path",
                node,
                round,
                format!("unrouted transfer {src}->{dst} (no path links)"),
            );
        }
        if bytes == 0 {
            rep.push(
                Severity::Warning,
                "zero-bytes",
                node,
                round,
                format!("zero-byte transfer {src}->{dst}"),
            );
        }
    }

    /// Validate a fault timeline against a topology before any solve
    /// runs (the same fail-fast posture as the workload passes): fire
    /// times must be finite and non-decreasing ([`FaultSchedule::at`]
    /// keeps them sorted, but `events` is public and hand-built
    /// schedules are not), every link/endpoint/node id must exist in
    /// the topology, degrade multipliers must sit in (0.0, 1.0] — a
    /// recovery "multiplier" above 1.0 would mint bandwidth — and a
    /// `LinkRecover` whose link was never taken down (by a prior
    /// `LinkDown`, `NicDown` or `NodeDown` expansion, or a degrade) is
    /// flagged as a warning: legal (it resets the multiplier to 1.0,
    /// a no-op on a healthy link) but almost always a typo'd link id.
    /// `node` in the diagnostics is the event's index in the schedule.
    pub fn analyze_faults(
        &self,
        fs: &FaultSchedule,
        topo: &Topology,
    ) -> AnalysisReport {
        let mut rep = AnalysisReport {
            nodes: fs.len(),
            ..Default::default()
        };
        let nics = topo.cfg.compute_endpoints() as u32;
        let nodes = (topo.cfg.compute_endpoints()
            / topo.cfg.nics_per_node) as u32;
        let mut last_t = f64::NEG_INFINITY;
        // links taken down (or degraded) so far, to anchor recoveries
        let mut downed: FxHashSet<LinkId> = FxHashSet::default();
        let mut expand: Vec<(LinkId, f64)> = Vec::new();
        for (i, ev) in fs.events.iter().enumerate() {
            let id = i as u32;
            if !ev.t.is_finite() {
                rep.push(
                    Severity::Error,
                    "bad-fault-time",
                    Some(id),
                    None,
                    format!("non-finite fire time {}", ev.t),
                );
            } else {
                if ev.t < last_t {
                    rep.push(
                        Severity::Error,
                        "fault-time-order",
                        Some(id),
                        None,
                        format!(
                            "fire time {} before previous event at {last_t} \
                             (the DES heap fires them out of schedule \
                             order; build with FaultSchedule::at)",
                            ev.t
                        ),
                    );
                }
                last_t = last_t.max(ev.t);
            }
            match &ev.kind {
                FaultKind::LinkDegrade { link, multiplier } => {
                    self.check_fault_link(&mut rep, topo, link, id);
                    let m = *multiplier;
                    if !m.is_finite() || m < 0.0 || m > 1.0 {
                        rep.push(
                            Severity::Error,
                            "bad-multiplier",
                            Some(id),
                            None,
                            format!(
                                "degrade multiplier {m} outside (0.0, 1.0] \
                                 (above 1.0 would mint bandwidth)"
                            ),
                        );
                    } else if m == 0.0 {
                        rep.push(
                            Severity::Warning,
                            "degrade-to-zero",
                            Some(id),
                            None,
                            format!(
                                "LinkDegrade to 0.0 on {link:?}: prefer \
                                 LinkDown, which states the intent"
                            ),
                        );
                    }
                    downed.insert(*link);
                }
                FaultKind::LinkDown { link } => {
                    self.check_fault_link(&mut rep, topo, link, id);
                    downed.insert(*link);
                }
                FaultKind::LinkRecover { link } => {
                    self.check_fault_link(&mut rep, topo, link, id);
                    if !downed.contains(link) {
                        rep.push(
                            Severity::Warning,
                            "recover-without-down",
                            Some(id),
                            None,
                            format!(
                                "LinkRecover on {link:?} with no prior \
                                 LinkDown/degrade of that link (no-op on \
                                 a healthy link — typo'd id?)"
                            ),
                        );
                    }
                }
                FaultKind::NicDown { endpoint } => {
                    if *endpoint >= nics {
                        rep.push(
                            Severity::Error,
                            "unknown-endpoint",
                            Some(id),
                            None,
                            format!(
                                "endpoint {endpoint} beyond the \
                                 topology's {nics} compute NICs"
                            ),
                        );
                    } else {
                        expand.clear();
                        ev.kind.link_multipliers(
                            topo.cfg.nics_per_node,
                            &mut expand,
                        );
                        downed.extend(expand.iter().map(|(l, _)| *l));
                    }
                }
                FaultKind::NodeDown { node } => {
                    if *node >= nodes {
                        rep.push(
                            Severity::Error,
                            "unknown-node",
                            Some(id),
                            None,
                            format!(
                                "node {node} beyond the topology's \
                                 {nodes} compute nodes"
                            ),
                        );
                    } else {
                        expand.clear();
                        ev.kind.link_multipliers(
                            topo.cfg.nics_per_node,
                            &mut expand,
                        );
                        downed.extend(expand.iter().map(|(l, _)| *l));
                    }
                }
            }
        }
        rep
    }

    /// Validate a [`ServicePolicy`] against the RPC mix it will govern
    /// (same fail-fast posture as the fault pass, run before the policy
    /// arms the executor). Per class: the deadline, hedge delay and
    /// retry budget must be non-negative and not NaN (`f64::INFINITY`
    /// is the documented "off" value); admission rate/burst must be
    /// positive when finite (a non-positive rate sheds *everything*, a
    /// burst below one token can never admit). A finite deadline
    /// shorter than the class's uncongested critical path —
    /// `bytes / min(rank_issue_bw, nic_eff_bw)`, the best any transfer
    /// of that size can do on an idle fabric — is a warning: every
    /// request of the class will be abandoned, healthy or not. `node`
    /// in the diagnostics is the class id; classes beyond the policy's
    /// table fall back to the all-off default and need no check.
    pub fn analyze_policies(
        &self,
        policy: &ServicePolicy,
        mix: &[RpcClass],
        topo: &Topology,
    ) -> AnalysisReport {
        let mut rep = AnalysisReport {
            nodes: policy.classes.len(),
            ..Default::default()
        };
        let best_bw = topo
            .cfg
            .rank_issue_bw_host
            .min(topo.cfg.nic_eff_bw_host);
        for (i, cp) in policy.classes.iter().enumerate() {
            let id = i as u32;
            for (name, v) in [
                ("deadline", cp.deadline),
                ("hedge delay", cp.hedge_delay),
                ("retry budget", cp.retry_budget),
            ] {
                if v.is_nan() || v < 0.0 {
                    rep.push(
                        Severity::Error,
                        "bad-policy-knob",
                        Some(id),
                        None,
                        format!(
                            "class {i}: {name} {v} must be non-negative \
                             (f64::INFINITY disables the control)"
                        ),
                    );
                }
            }
            if cp.deadline == 0.0 || cp.hedge_delay == 0.0 {
                rep.push(
                    Severity::Error,
                    "bad-policy-knob",
                    Some(id),
                    None,
                    format!(
                        "class {i}: zero deadline/hedge delay fires at the \
                         arrival instant — no request can ever run"
                    ),
                );
            }
            if cp.admit_rate.is_nan()
                || cp.admit_rate <= 0.0
                || (cp.admit_rate.is_finite() && cp.admit_burst < 1.0)
            {
                rep.push(
                    Severity::Error,
                    "bad-admission",
                    Some(id),
                    None,
                    format!(
                        "class {i}: admission rate {} / burst {} (rate must \
                         be positive, burst >= 1 token when the rate is \
                         finite)",
                        cp.admit_rate, cp.admit_burst
                    ),
                );
            }
            if let Some(rc) = mix.get(i) {
                let floor = rc.bytes as f64 / best_bw;
                if cp.deadline.is_finite() && cp.deadline < floor {
                    rep.push(
                        Severity::Warning,
                        "deadline-unreachable",
                        Some(id),
                        None,
                        format!(
                            "class {i}: deadline {:.3e}s is below the \
                             uncongested critical path {floor:.3e}s for \
                             {} bytes — every request will be abandoned",
                            cp.deadline, rc.bytes
                        ),
                    );
                }
            }
        }
        rep
    }

    fn check_fault_link(
        &self,
        rep: &mut AnalysisReport,
        topo: &Topology,
        link: &LinkId,
        id: u32,
    ) {
        if !topo.contains_link(link) {
            rep.push(
                Severity::Error,
                "unknown-link",
                Some(id),
                None,
                format!("{link:?} is not a link of this topology"),
            );
        }
    }

    fn check_binding(
        &self,
        rep: &mut AnalysisReport,
        key_nic: &mut FxHashMap<u32, u32>,
        key: u32,
        nic: u32,
        node: u32,
        round: u32,
    ) {
        match key_nic.get(&key) {
            None => {
                key_nic.insert(key, nic);
            }
            Some(&prev) if prev != nic => {
                rep.push(
                    Severity::Warning,
                    "key-aliasing",
                    Some(node),
                    Some(round),
                    format!(
                        "key {key} bound to NIC {nic} after NIC {prev} \
                         (one logical endpoint on two NICs)"
                    ),
                );
            }
            Some(_) => {}
        }
    }
}

// --------------------------------------------- collective byte budgets

/// Which collective a round list claims to implement — selects the
/// closed-form per-rank byte budget [`check_collective_rounds`]
/// verifies (the paper's §5.1 algorithms, as generated by
/// `mpi::coll::*_rounds`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// 2(P-1) shift-by-one rounds of `max(bytes/P, 1)` chunks: every
    /// rank moves exactly `2*(P-1)/P * bytes` (up to chunk rounding).
    AllreduceRing,
    /// Remainder fold-in, log2(P2) exchange rounds, fold-out.
    AllreduceTree,
    /// P-1 rotation rounds; every ordered pair exactly once.
    Alltoall,
    /// P-1 shift-by-one rounds of `bytes` per rank.
    Allgather,
    /// P-1 shift-by-one rounds of `max(bytes/P, 1)` chunks.
    ReduceScatter,
    /// Binomial tree: P-1 messages total, every non-root rank receives
    /// exactly once.
    Bcast,
}

/// Rank keys of a per-rank accounting map in sorted order, so
/// diagnostics report ranks smallest-first regardless of hash order.
fn sorted_keys(m: &FxHashMap<usize, u64>) -> Vec<usize> {
    let mut ks: Vec<usize> = m.keys().copied().collect();
    ks.sort_unstable();
    ks
}

/// Verify the byte-conservation identity of a collective's round list
/// (world-rank triples, as produced by `mpi::coll::*_rounds`): per-rank
/// sent/received byte totals match the algorithm's closed form, and the
/// permutation rounds really are permutations (each participating rank
/// sends and receives at most once per round). `bytes` is the
/// collective's input size argument (per-rank payload for allgather).
pub fn check_collective_rounds(
    kind: Collective,
    p: usize,
    bytes: u64,
    rounds: &[Vec<(usize, usize, u64)>],
) -> AnalysisReport {
    let mut rep = AnalysisReport {
        rounds: rounds.len(),
        ..Default::default()
    };
    if p <= 1 {
        if !rounds.is_empty() {
            rep.push(
                Severity::Error,
                "coll-shape",
                None,
                None,
                format!("{} round(s) for a {p}-rank collective", rounds.len()),
            );
        }
        return rep;
    }
    let chunk = (bytes / p as u64).max(1);
    let p2 = {
        let mut v = 1usize;
        while v * 2 <= p {
            v *= 2;
        }
        v
    };
    let log2p2 = p2.trailing_zeros() as u64;

    // ---- expected shape ----
    let expect_rounds = match kind {
        Collective::AllreduceRing => 2 * (p - 1),
        Collective::AllreduceTree => {
            log2p2 as usize + if p > p2 { 2 } else { 0 }
        }
        Collective::Alltoall | Collective::Allgather
        | Collective::ReduceScatter => p - 1,
        Collective::Bcast => p.next_power_of_two().trailing_zeros() as usize,
    };
    if rounds.len() != expect_rounds {
        rep.push(
            Severity::Error,
            "coll-shape",
            None,
            None,
            format!(
                "{:?}: {} round(s), expected {expect_rounds} for P={p}",
                kind,
                rounds.len()
            ),
        );
    }

    // ---- per-rank accounting + per-round permutation check ----
    let mut sent: FxHashMap<usize, u64> = FxHashMap::default();
    let mut recv: FxHashMap<usize, u64> = FxHashMap::default();
    let mut pairs: FxHashMap<(usize, usize), u32> = FxHashMap::default();
    for (k, round) in rounds.iter().enumerate() {
        let rk = k as u32;
        let mut round_src: FxHashMap<usize, u32> = FxHashMap::default();
        let mut round_dst: FxHashMap<usize, u32> = FxHashMap::default();
        for (i, &(s, d, b)) in round.iter().enumerate() {
            rep.nodes += 1;
            if s == d {
                rep.push(
                    Severity::Error,
                    "self-flow",
                    Some(i as u32),
                    Some(rk),
                    format!("rank {s} sends to itself"),
                );
            }
            *sent.entry(s).or_default() += b;
            *recv.entry(d).or_default() += b;
            *pairs.entry((s, d)).or_default() += 1;
            *round_src.entry(s).or_default() += 1;
            *round_dst.entry(d).or_default() += 1;
        }
        // sorted walk: diagnostics come out in rank order, not the
        // (deterministic but unsorted) hash order
        let mut sides: Vec<(usize, u32)> = round_src
            .iter()
            .chain(round_dst.iter())
            .map(|(&r, &c)| (r, c))
            .collect();
        sides.sort_unstable();
        for (r, c) in sides {
            if c > 1 {
                rep.push(
                    Severity::Error,
                    "coll-permutation",
                    None,
                    Some(rk),
                    format!(
                        "rank {r} appears {c} times on one side of round \
                         {k} (rounds must be permutations)"
                    ),
                );
            }
        }
    }

    // ---- closed-form per-rank budgets ----
    let mut expect_sent = |rep: &mut AnalysisReport, rank: usize, want: u64| {
        let got = sent.get(&rank).copied().unwrap_or(0);
        if got != want {
            rep.push(
                Severity::Error,
                "coll-bytes",
                None,
                None,
                format!(
                    "{kind:?}: rank {rank} sent {got} bytes, expected \
                     {want} (P={p}, bytes={bytes})"
                ),
            );
        }
    };
    match kind {
        Collective::AllreduceRing => {
            // the paper's 2(P-1)/P * bytes identity, exact in chunks
            for r in sorted_keys(&sent) {
                expect_sent(&mut rep, r, 2 * (p as u64 - 1) * chunk);
            }
            if sent.len() != p {
                rep.push(
                    Severity::Error,
                    "coll-bytes",
                    None,
                    None,
                    format!(
                        "AllreduceRing: {} of {p} ranks ever send",
                        sent.len()
                    ),
                );
            }
        }
        Collective::ReduceScatter => {
            for r in sorted_keys(&sent) {
                expect_sent(&mut rep, r, (p as u64 - 1) * chunk);
            }
        }
        Collective::Allgather => {
            for r in sorted_keys(&sent) {
                expect_sent(&mut rep, r, (p as u64 - 1) * bytes);
            }
        }
        Collective::Alltoall => {
            // every ordered pair exactly once at `bytes` each
            let mut missing = 0usize;
            for s in 0..p {
                for d in 0..p {
                    if s == d {
                        continue;
                    }
                    match pairs.get(&(s, d)) {
                        Some(&1) => {}
                        Some(&c) => rep.push(
                            Severity::Error,
                            "coll-bytes",
                            None,
                            None,
                            format!("Alltoall: pair ({s},{d}) sent {c} times"),
                        ),
                        None => missing += 1,
                    }
                }
            }
            if missing > 0 {
                rep.push(
                    Severity::Error,
                    "coll-bytes",
                    None,
                    None,
                    format!("Alltoall: {missing} ordered pair(s) never sent"),
                );
            }
        }
        Collective::Bcast => {
            let total: u64 = sent.values().sum();
            if total != (p as u64 - 1) * bytes {
                rep.push(
                    Severity::Error,
                    "coll-bytes",
                    None,
                    None,
                    format!(
                        "Bcast: {total} total bytes, expected {}",
                        (p as u64 - 1) * bytes
                    ),
                );
            }
            for r in sorted_keys(&recv) {
                let c = recv[&r];
                if c != bytes {
                    rep.push(
                        Severity::Error,
                        "coll-bytes",
                        None,
                        None,
                        format!(
                            "Bcast: rank {r} received {c} bytes, expected \
                             exactly {bytes} (every non-root receives once)"
                        ),
                    );
                }
            }
            if recv.len() != p - 1 {
                rep.push(
                    Severity::Error,
                    "coll-bytes",
                    None,
                    None,
                    format!(
                        "Bcast: {} rank(s) receive, expected {}",
                        recv.len(),
                        p - 1
                    ),
                );
            }
        }
        Collective::AllreduceTree => {
            // power-of-two participants exchange `bytes` in each of the
            // log2(P2) rounds; each remainder rank sends one fold-in
            // and receives one fold-out message
            let rem = p - p2;
            let total: u64 = sent.values().sum();
            let want =
                (p2 as u64 * log2p2 + 2 * rem as u64) * bytes;
            if total != want {
                rep.push(
                    Severity::Error,
                    "coll-bytes",
                    None,
                    None,
                    format!(
                        "AllreduceTree: {total} total bytes, expected \
                         {want} (P={p}, P2={p2})"
                    ),
                );
            }
        }
    }
    rep
}

// ------------------------------------------------- executor self-checks

/// `debug_assertions` hook for every `run_dag` entry: panic with the
/// rendered report if the workload violates an executor contract.
#[cfg(debug_assertions)]
pub(crate) fn debug_check_dag(wl: &DagWorkload) {
    let rep = WorkloadAnalyzer::new().analyze_dag(wl);
    assert!(
        rep.is_clean(),
        "workload verifier rejected the DAG before execution:\n{}",
        rep.render()
    );
}

/// `debug_assertions` hook for every streamed round as it materializes
/// (a live source cannot be pre-analyzed without consuming it): the
/// cheap structural subset — sentinel misuse, self-flows, unrouted
/// paths, bad floors — checked per round.
#[cfg(debug_assertions)]
pub(crate) fn debug_check_round(round: &[StreamNode], round_idx: u32) {
    let a = WorkloadAnalyzer::new();
    let mut rep = AnalysisReport::default();
    for (i, n) in round.iter().enumerate() {
        let id = i as u32;
        let (ka, kb, floor) = match n {
            StreamNode::Compute { a, b, start, .. } => (*a, *b, *start),
            StreamNode::Xfer { a, b, rf, start } => {
                a.check_xfer(
                    &mut rep,
                    rf.flow.src_nic,
                    rf.flow.dst_nic,
                    rf.flow.bytes,
                    rf.path.links.len(),
                    Some(id),
                    Some(round_idx),
                );
                (*a, *b, *start)
            }
        };
        a.check_floor(&mut rep, floor, Some(id), Some(round_idx));
        if (ka == NO_KEY) != (kb == NO_KEY) {
            rep.push(
                Severity::Error,
                "no-key-misuse",
                Some(id),
                Some(round_idx),
                format!("half-sentinel keys ({ka}, {kb})"),
            );
        }
    }
    assert!(
        rep.is_clean(),
        "workload verifier rejected streamed round {round_idx}:\n{}",
        rep.render()
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::fabric::workload::{self, DagNode};
    use crate::fabric::{Flow, RoutedFlow, Router};
    use crate::topology::Topology;

    fn topo() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    fn routed(r: &mut Router, s: u32, d: u32, bytes: u64) -> RoutedFlow {
        let f = Flow::new(s, d, bytes);
        RoutedFlow { path: r.route(&f), flow: f }
    }

    #[test]
    fn clean_ring_dag_passes() {
        let t = topo();
        let mut r = Router::new(&t);
        let nics = workload::spread_nics(&t, 8);
        let wl = workload::dag_from_rounds(
            &mut r,
            &workload::ring_rounds(&nics, 3, 4096),
            0.0,
        );
        let rep = WorkloadAnalyzer::new().analyze_dag(&wl);
        assert!(rep.is_clean(), "{}", rep.render());
        assert_eq!(rep.nodes, wl.len());
        assert_eq!(rep.warnings(), 0, "{}", rep.render());
    }

    #[test]
    fn cycle_is_rejected_with_structured_diag() {
        // build a 2-cycle by bypassing `push` (the `nodes` field is
        // public): node 0 <-> node 1
        let t = topo();
        let mut r = Router::new(&t);
        let mut wl = DagWorkload::new();
        wl.nodes.push(DagNode {
            kind: DagKind::Xfer(routed(&mut r, 0, 200, 4096)),
            deps: vec![1],
            start: 0.0,
        });
        wl.nodes.push(DagNode {
            kind: DagKind::Compute(1.0),
            deps: vec![0],
            start: 0.0,
        });
        let rep = WorkloadAnalyzer::new().analyze_dag(&wl);
        assert!(!rep.is_clean());
        assert!(
            rep.diags.iter().any(|d| d.check == "cycle"
                && d.severity == Severity::Error
                && d.node.is_some()),
            "{}",
            rep.render()
        );
        // the forward-dep contract check fires too (node 0 -> 1)
        assert!(rep.diags.iter().any(|d| d.check == "forward-dep"));
    }

    #[test]
    fn dangling_dep_self_flow_and_bad_floor_are_errors() {
        let t = topo();
        let mut r = Router::new(&t);
        let mut wl = DagWorkload::new();
        wl.nodes.push(DagNode {
            kind: DagKind::Xfer(routed(&mut r, 7, 7, 4096)), // self-flow
            deps: vec![42],                                  // dangling
            start: f64::NAN,                                 // bad floor
        });
        let rep = WorkloadAnalyzer::new().analyze_dag(&wl);
        for check in ["dangling-dep", "self-flow", "bad-floor"] {
            assert!(
                rep.diags.iter().any(|d| d.check == check
                    && d.severity == Severity::Error),
                "missing {check}: {}",
                rep.render()
            );
        }
    }

    #[test]
    fn half_sentinel_round_is_rejected() {
        let t = topo();
        let mut r = Router::new(&t);
        let rounds = vec![vec![StreamNode::Xfer {
            a: NO_KEY,
            b: 3,
            rf: routed(&mut r, 0, 200, 4096),
            start: 0.0,
        }]];
        let rep = WorkloadAnalyzer::new().analyze_rounds(&rounds);
        assert!(!rep.is_clean());
        assert!(
            rep.diags.iter().any(|d| d.check == "no-key-misuse"
                && d.round == Some(0)),
            "{}",
            rep.render()
        );
    }

    #[test]
    fn sparse_key_gap_warns() {
        let t = topo();
        let mut r = Router::new(&t);
        let a = WorkloadAnalyzer { sparse_key_gap: 4 };
        let mk = |r: &mut Router| StreamNode::Xfer {
            a: 0,
            b: 1,
            rf: routed(r, 0, 200, 4096),
            start: 0.0,
        };
        let mut rounds = vec![vec![mk(&mut r)]];
        for _ in 0..6 {
            rounds.push(vec![StreamNode::Compute {
                a: 9,
                b: 9,
                dt: 0.1,
                start: 0.0,
            }]);
        }
        rounds.push(vec![mk(&mut r)]); // keys 0/1 idle for 7 > 4 rounds
        let rep = a.analyze_rounds(&rounds);
        assert!(rep.is_clean(), "sparse keys are a warning, not an error");
        assert!(
            rep.diags.iter().any(|d| d.check == "sparse-key"),
            "{}",
            rep.render()
        );
    }

    #[test]
    fn key_aliasing_binding_conflict_warns() {
        let t = topo();
        let mut r = Router::new(&t);
        let rounds = vec![
            vec![StreamNode::Xfer {
                a: 5,
                b: 6,
                rf: routed(&mut r, 0, 200, 4096),
                start: 0.0,
            }],
            vec![StreamNode::Xfer {
                a: 5, // same key, different source NIC
                b: 6,
                rf: routed(&mut r, 8, 200, 4096),
                start: 0.0,
            }],
        ];
        let rep = WorkloadAnalyzer::new().analyze_rounds(&rounds);
        assert!(
            rep.diags.iter().any(|d| d.check == "key-aliasing"),
            "{}",
            rep.render()
        );
    }

    #[test]
    fn throttled_source_empty_round_is_deadlock_hazard() {
        struct Empties(u32);
        impl RoundSource for Empties {
            fn next_round(&mut self) -> Option<Vec<StreamNode>> {
                if self.0 == 0 {
                    return None;
                }
                self.0 -= 1;
                Some(Vec::new())
            }
            fn next_round_not_before(&mut self) -> f64 {
                1.0
            }
        }
        let rep =
            WorkloadAnalyzer::new().analyze_source(&mut Empties(3), 16);
        assert!(!rep.is_clean());
        assert!(
            rep.diags.iter().any(|d| d.check == "empty-round"
                && d.severity == Severity::Error),
            "{}",
            rep.render()
        );
    }

    #[test]
    fn non_monotone_not_before_is_error() {
        let t = topo();
        let mut r = Router::new(&t);
        let rf = routed(&mut r, 0, 200, 4096);
        struct Back {
            k: u32,
            rf: RoutedFlow,
        }
        impl RoundSource for Back {
            fn next_round(&mut self) -> Option<Vec<StreamNode>> {
                if self.k >= 3 {
                    return None;
                }
                self.k += 1;
                Some(vec![StreamNode::Xfer {
                    a: NO_KEY,
                    b: NO_KEY,
                    rf: self.rf.clone(),
                    start: 10.0,
                }])
            }
            fn next_round_not_before(&mut self) -> f64 {
                // 5.0, 4.0, 3.0, ... — goes backwards
                5.0 - self.k as f64
            }
        }
        let rep = WorkloadAnalyzer::new()
            .analyze_source(&mut Back { k: 0, rf }, 16);
        assert!(
            rep.diags.iter().any(|d| d.check == "non-monotone-not-before"
                && d.severity == Severity::Error),
            "{}",
            rep.render()
        );
    }

    #[test]
    fn fault_timeline_checks_fire() {
        use crate::fabric::faults::{FaultEvent, FaultPolicy};
        let t = topo();
        let mut fs = FaultSchedule::new(FaultPolicy::Reroute);
        // hand-built events list bypassing `at` (the field is public),
        // packing one instance of every defect
        fs.events = vec![
            FaultEvent {
                t: 1.0,
                kind: FaultKind::LinkDegrade {
                    link: LinkId::NicUp(0),
                    multiplier: 1.5,
                },
            },
            FaultEvent {
                t: 0.5, // before the previous event
                kind: FaultKind::LinkRecover { link: LinkId::NicUp(1) },
            },
            FaultEvent {
                t: f64::NAN,
                kind: FaultKind::NicDown { endpoint: 1 << 30 },
            },
            FaultEvent {
                t: 2.0,
                kind: FaultKind::LinkDown {
                    link: LinkId::Global { src: 40, dst: 41, idx: 0 },
                },
            },
            FaultEvent {
                t: 3.0,
                kind: FaultKind::NodeDown { node: 1 << 30 },
            },
        ];
        let rep = WorkloadAnalyzer::new().analyze_faults(&fs, &t);
        for check in [
            "bad-multiplier",
            "fault-time-order",
            "bad-fault-time",
            "unknown-endpoint",
            "unknown-link",
            "unknown-node",
        ] {
            assert!(
                rep.diags.iter().any(|d| d.check == check
                    && d.severity == Severity::Error),
                "missing {check}: {}",
                rep.render()
            );
        }
        assert!(
            rep.diags.iter().any(|d| d.check == "recover-without-down"
                && d.severity == Severity::Warning),
            "{}",
            rep.render()
        );
    }

    #[test]
    fn clean_fault_schedule_passes() {
        use crate::fabric::faults::FaultPolicy;
        let t = topo();
        let fs = FaultSchedule::new(FaultPolicy::Abort)
            .at(0.0, FaultKind::LinkDown { link: LinkId::NicUp(0) })
            .at(1.0, FaultKind::LinkRecover { link: LinkId::NicUp(0) })
            .at(2.0, FaultKind::NicDown { endpoint: 3 })
            // recovery of a link the NicDown expansion took down
            .at(3.0, FaultKind::LinkRecover { link: LinkId::NicDown(3) });
        let rep = WorkloadAnalyzer::new().analyze_faults(&fs, &t);
        assert!(rep.is_clean(), "{}", rep.render());
        assert_eq!(rep.warnings(), 0, "{}", rep.render());
        assert_eq!(rep.nodes, fs.len());
    }

    #[test]
    fn render_carries_ids_and_counts() {
        let mut wl = DagWorkload::new();
        wl.nodes.push(DagNode {
            kind: DagKind::Compute(1.0),
            deps: vec![9],
            start: 0.0,
        });
        let rep = WorkloadAnalyzer::new().analyze_dag(&wl);
        let text = rep.render();
        assert!(text.contains("error[dangling-dep] node 0"), "{text}");
        assert!(text.contains("error(s)"), "{text}");
    }
}
