//! Round-based contention tier + the shared message cost model.
//!
//! A "round" is a set of flows that start together (one round of a
//! collective, one superstep of an application). Completion time per flow
//! is its zero-load latency plus the bottleneck service time along its
//! path, with every endpoint effect of paper §5.1 applied:
//!
//! * per-rank issue ceiling (one rank cannot saturate a NIC — Fig 11/12),
//! * host vs GPU effective NIC bandwidth (PCIe Gen4<->Gen5 conversion,
//!   Fig 13),
//! * NIC SRAM -> host DRAM eager-buffer spill latency step (Fig 10),
//! * eager -> rendezvous protocol switch (extra RTT),
//! * per-NIC message-rate ceiling (bounds tiny-message all2all).

use super::{BufLoc, Flow, FlowTimes, RoutedFlow, SparseLoadMap};
use crate::topology::{Path, Topology};
use std::collections::BTreeMap;

/// Zero-load + contention cost evaluation, shared by all tiers.
pub struct CostModel<'t> {
    pub topo: &'t Topology,
}

impl<'t> CostModel<'t> {
    pub fn new(topo: &'t Topology) -> Self {
        Self { topo }
    }

    /// Per-direction effective NIC bandwidth for a buffer location.
    pub fn nic_eff_bw(&self, buf: BufLoc) -> f64 {
        let c = &self.topo.cfg;
        match buf {
            BufLoc::Host => c.nic_eff_bw_host,
            BufLoc::Gpu => c.nic_eff_bw_gpu,
        }
    }

    /// Per-rank issue ceiling (software + PCIe doorbell path).
    pub fn rank_issue_bw(&self, buf: BufLoc) -> f64 {
        let c = &self.topo.cfg;
        match buf {
            BufLoc::Host => c.rank_issue_bw_host,
            BufLoc::Gpu => c.rank_issue_bw_gpu,
        }
    }

    /// Zero-load end-to-end latency for one message on `path`.
    ///
    /// Reproduces the Fig 10 structure: flat for <= 64 B (Cassini SRAM
    /// buffering), a step at 128 B (host-DRAM spill), rendezvous RTT above
    /// the eager threshold, then bandwidth-dominated.
    pub fn msg_latency(&self, path: &Path, bytes: u64, buf: BufLoc) -> f64 {
        let c = &self.topo.cfg;
        let mut t = c.mpi_overhead + 2.0 * c.nic_latency
            + self.topo.path_latency(path);
        if bytes > c.nic_sram_msg_bytes {
            t += c.dram_spill_penalty;
        }
        if matches!(buf, BufLoc::Gpu) {
            // GPU-direct doorbell + PCIe conversion adds fixed cost
            t += 0.6e-6;
        }
        if bytes > c.eager_threshold {
            // rendezvous: RTS/CTS round trip before the payload moves
            t += 2.0 * (c.mpi_overhead + 2.0 * c.nic_latency
                + self.topo.path_latency(path));
        }
        t
    }

    /// Single-flow serialization time (no cross-flow contention).
    pub fn solo_serialization(&self, bytes: u64, buf: BufLoc) -> f64 {
        bytes as f64 / self.rank_issue_bw(buf).min(self.nic_eff_bw(buf))
    }

    /// Uncontended point-to-point message time.
    pub fn solo_msg_time(&self, path: &Path, bytes: u64, buf: BufLoc) -> f64 {
        self.msg_latency(path, bytes, buf) + self.solo_serialization(bytes, buf)
    }

    /// Evaluate one round of concurrent flows.
    ///
    /// Per-flow completion = zero-load latency + bottleneck service time,
    /// where each link's service time is (total bytes crossing it) / bw,
    /// NIC links additionally respect message-rate and effective-bandwidth
    /// ceilings, and each flow respects its rank issue ceiling.
    pub fn eval_round(&self, flows: &[RoutedFlow]) -> FlowTimes {
        // sparse: these are per-call accumulators — the dense LoadMap
        // would allocate the whole link universe on every evaluation
        let mut bytes_on = SparseLoadMap::new();
        let mut msgs_on = SparseLoadMap::new();
        for rf in flows {
            bytes_on.add_path(&rf.path.links, rf.flow.bytes as f64);
            // message-rate pressure only matters at the NIC endpoints
            msgs_on.add(rf.path.links[0], 1.0);
            msgs_on.add(*rf.path.links.last().unwrap(), 1.0);
        }
        let per_flow = flows
            .iter()
            .map(|rf| {
                let mut service: f64 = rf.flow.bytes as f64
                    / self.rank_issue_bw(rf.flow.buf);
                for l in &rf.path.links {
                    let bw = match l {
                        crate::topology::LinkId::NicUp(_)
                        | crate::topology::LinkId::NicDown(_) => {
                            self.nic_eff_bw(rf.flow.buf)
                        }
                        _ => self.topo.link_bw(l),
                    };
                    let mut t = bytes_on.get(l) / bw;
                    let m = msgs_on.get(l);
                    if m > 0.0 {
                        t = t.max(m / self.topo.cfg.nic_msg_rate);
                    }
                    service = service.max(t);
                }
                self.msg_latency(&rf.path, rf.flow.bytes, rf.flow.buf) + service
            })
            .collect();
        FlowTimes::from_vec(per_flow)
    }

    /// Pessimistic completion estimate for *timed* flows: per-flow
    /// completion = start + zero-load latency + bottleneck service, with
    /// every flow's load counted on its links regardless of temporal
    /// overlap, and link bandwidths scaled by the same `degraded`
    /// multipliers the DES applies (pass `DesOpts::degraded` so the two
    /// tiers price the same fabric). Nearly always an over-estimate,
    /// since flows disjoint in time do not actually contend — though not
    /// a strict bound: when a sharing flow completes and leaves the
    /// survivor issue-cap-limited, the link runs unsaturated and DES can
    /// finish marginally later. The campaign engine uses it as a cheap
    /// cross-tier sanity bracket on each scenario's DES makespan.
    pub fn eval_timed(
        &self,
        flows: &[super::des::TimedFlow],
        degraded: &BTreeMap<crate::topology::LinkId, f64>,
    ) -> FlowTimes {
        let mut bytes_on = SparseLoadMap::new();
        let mut msgs_on = SparseLoadMap::new();
        for tf in flows {
            bytes_on.add_path(&tf.rf.path.links, tf.rf.flow.bytes as f64);
            msgs_on.add(tf.rf.path.links[0], 1.0);
            msgs_on.add(*tf.rf.path.links.last().unwrap(), 1.0);
        }
        let per_flow = flows
            .iter()
            .map(|tf| {
                let rf = &tf.rf;
                let mut service: f64 = rf.flow.bytes as f64
                    / self.rank_issue_bw(rf.flow.buf);
                for l in &rf.path.links {
                    let bw = match l {
                        crate::topology::LinkId::NicUp(_)
                        | crate::topology::LinkId::NicDown(_) => {
                            self.nic_eff_bw(rf.flow.buf)
                        }
                        _ => self.topo.link_bw(l),
                    } * degraded.get(l).copied().unwrap_or(1.0);
                    let mut t = bytes_on.get(l) / bw;
                    let m = msgs_on.get(l);
                    if m > 0.0 {
                        t = t.max(m / self.topo.cfg.nic_msg_rate);
                    }
                    service = service.max(t);
                }
                tf.start
                    + self.msg_latency(&rf.path, rf.flow.bytes, rf.flow.buf)
                    + service
            })
            .collect();
        FlowTimes::from_vec(per_flow)
    }

    /// Route (adaptively) and evaluate a round in one step.
    pub fn run_round(
        &self,
        router: &mut super::Router<'t>,
        flows: &[Flow],
    ) -> FlowTimes {
        let routed: Vec<RoutedFlow> = flows
            .iter()
            .map(|f| RoutedFlow { flow: f.clone(), path: router.route(f) })
            .collect();
        self.eval_round(&routed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::fabric::Router;

    fn topo() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn latency_flat_then_steps_at_128b() {
        let t = topo();
        let cm = CostModel::new(&t);
        let p = t.minimal_path(0, 200, 0);
        let l8 = cm.msg_latency(&p, 8, BufLoc::Host);
        let l64 = cm.msg_latency(&p, 64, BufLoc::Host);
        let l128 = cm.msg_latency(&p, 128, BufLoc::Host);
        assert_eq!(l8, l64, "SRAM-buffered sizes share latency");
        assert!(
            l128 > l64 + 0.5e-6,
            "Fig 10 jump missing: {l64} -> {l128}"
        );
    }

    #[test]
    fn small_message_latency_is_microseconds() {
        // Fig 10: small-message latency is a few microseconds
        let t = topo();
        let cm = CostModel::new(&t);
        let p = t.minimal_path(0, 200, 0);
        let l = cm.msg_latency(&p, 8, BufLoc::Host);
        assert!(l > 1e-6 && l < 6e-6, "latency {l}");
    }

    #[test]
    fn rendezvous_adds_round_trip() {
        let t = topo();
        let cm = CostModel::new(&t);
        let p = t.minimal_path(0, 200, 0);
        let eager = cm.msg_latency(&p, 8 * 1024, BufLoc::Host);
        let rndv = cm.msg_latency(&p, 8 * 1024 + 1, BufLoc::Host);
        assert!(rndv > eager * 1.8);
    }

    #[test]
    fn two_flows_share_a_nic() {
        let t = topo();
        let cm = CostModel::new(&t);
        let mut r = Router::new(&t);
        let big = 64 << 20;
        let one = cm.run_round(&mut r, &[Flow::new(0, 200, big)]);
        let mut r2 = Router::new(&t);
        let two = cm.run_round(
            &mut r2,
            &[Flow::new(0, 200, big), Flow::new(0, 201, big)],
        );
        // same source NIC: the NIC (22.5 GB/s eff) is now the bottleneck
        // instead of the per-rank issue rate (14 GB/s): 2*14/22.5 ~ 1.24x
        assert!(two.makespan > one.makespan * 1.15, "{} vs {}", two.makespan,
            one.makespan);
    }

    #[test]
    fn single_rank_cannot_saturate_nic() {
        // Fig 11/12: per-rank issue bw < NIC effective bw
        let t = topo();
        let cm = CostModel::new(&t);
        let mut r = Router::new(&t);
        let bytes = 256 << 20;
        let solo = cm.run_round(&mut r, &[Flow::new(0, 200, bytes)]);
        let rate = bytes as f64 / solo.makespan;
        assert!(rate < t.cfg.nic_eff_bw_host * 0.75, "rate {rate}");
    }

    #[test]
    fn gpu_buffers_are_slower_than_host() {
        let t = topo();
        let cm = CostModel::new(&t);
        let bytes = 64 << 20;
        let mut r1 = Router::new(&t);
        let host = cm.run_round(&mut r1, &[Flow::new(0, 200, bytes)]);
        let mut r2 = Router::new(&t);
        let gpu = cm.run_round(&mut r2, &[Flow::new(0, 200, bytes).gpu()]);
        assert!(gpu.makespan > host.makespan);
    }

    #[test]
    fn eval_timed_bounds_des_and_shifts_by_start() {
        use crate::fabric::des::{DesOpts, DesSim, TimedFlow};
        let t = topo();
        let cm = CostModel::new(&t);
        let mut r = Router::new(&t);
        let flows = [Flow::new(0, 200, 8 << 20), Flow::new(8, 208, 8 << 20)];
        let timed: Vec<TimedFlow> = flows
            .iter()
            .enumerate()
            .map(|(i, f)| TimedFlow {
                rf: RoutedFlow { path: r.route(f), flow: f.clone() },
                start: i as f64 * 0.25,
            })
            .collect();
        let ub = cm.eval_timed(&timed, &BTreeMap::new());
        assert!(ub.per_flow[1] >= 0.25, "start must shift the bound");
        let des = DesSim::new(&t, DesOpts::default()).run(&timed);
        for (i, (&u, &d)) in
            ub.per_flow.iter().zip(des.finish.iter()).enumerate()
        {
            assert!(u >= d * 0.999, "flow {i}: UB {u} < DES {d}");
        }
    }

    #[test]
    fn message_rate_bounds_tiny_flows() {
        let t = topo();
        let cm = CostModel::new(&t);
        // 10k 8-byte flows from one NIC: rate-limited, not bandwidth-limited
        let flows: Vec<RoutedFlow> = (0..10_000)
            .map(|i| {
                let f = Flow::new(0, 200 + (i % 8) as u32, 8);
                let path = t.minimal_path(0, 200 + (i % 8) as u32, 0);
                RoutedFlow { flow: f, path }
            })
            .collect();
        let times = cm.eval_round(&flows);
        let rate_bound = 10_000.0 / t.cfg.nic_msg_rate;
        assert!(times.makespan >= rate_bound, "{} < {rate_bound}",
            times.makespan);
    }
}
