//! Graceful degradation for the open-loop service tier (EXPERIMENTS.md
//! §Graceful degradation).
//!
//! A production service riding the fabric does not let an outage turn
//! into an unbounded queue: it *sheds* load it cannot serve, *abandons*
//! requests that already missed their SLO, *bounds* how much retry
//! traffic a fault may amplify into, and *hedges* stragglers onto
//! disjoint paths. A [`ServicePolicy`] is the per-[`RpcClass`]
//! description of those four controls; the open-loop executor
//! (`fabric::des` streaming path + `fabric::arrivals`) enforces them:
//!
//! * **Admission control** — a deterministic token bucket
//!   ([`Admission`]) plus a backlog threshold, evaluated by
//!   `OpenLoopSource` *at arrival time, before routing*: a shed arrival
//!   never materializes a node, never touches the router and never
//!   enters the solver. Counted per class as `shed`.
//! * **Deadlines** — an `EV_DEADLINE` heap event scheduled at
//!   `arrival + deadline` abandons a request still in flight: its flows
//!   detach (delivered bytes synced, bandwidth freed for survivors) and
//!   the affected components re-solve, exactly like the fault sweep.
//!   Counted per class as `abandoned`; excluded from the latency
//!   histogram.
//! * **Retry budgets** — `RetryBackoff` retries consume a per-class
//!   budget shared across *all* flows of the class; once it is spent, a
//!   flow that would re-arm its backoff fails instead. A retry storm
//!   cannot amplify an outage past the budget.
//! * **Hedging** — an `EV_HEDGE` event duplicates a still-running
//!   request onto the first minimal candidate route sharing no fabric
//!   link with the primary (NIC links are necessarily shared). First
//!   completion wins; the loser is detached and its slot recycled.
//!
//! Determinism: a policy is plain data; the token bucket is a pure
//! function of the (deterministic) arrival sequence; the new heap
//! events validate against the flow's *node id* so slot recycling can
//! never mis-deliver one; and an inert policy ([`ServicePolicy::
//! is_inert`]) schedules no events and sheds nothing, so it is
//! bit-identical to running with no policy at all (pinned by
//! `tests/open_loop.rs` and the `degrade_overhead` bench gate).

use super::arrivals::RpcClass;

/// Per-class overload controls. Every knob defaults to *off*
/// (`INFINITY` / `u64::MAX`), so `ClassPolicy::default()` changes
/// nothing — the executor schedules no events and the admission layer
/// sheds nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassPolicy {
    /// Token-bucket refill rate, admitted arrivals/second
    /// (`INFINITY` = no rate limit).
    pub admit_rate: f64,
    /// Token-bucket depth: the burst the class may admit above its
    /// sustained rate (`>= 1` whenever `admit_rate` is finite).
    pub admit_burst: f64,
    /// Shed every arrival while the class backlog (accepted, not yet
    /// completed/failed/abandoned) is at or above this
    /// (`u64::MAX` = no threshold).
    pub backlog_limit: u64,
    /// Request SLO: a flow still in flight `deadline` seconds after its
    /// arrival floor is abandoned (`INFINITY` = no deadline).
    pub deadline: f64,
    /// Shared per-class retry budget for the fault policy's
    /// `RetryBackoff` re-arms (`INFINITY` = unbounded; consumed one
    /// unit per scheduled retry, across all flows of the class).
    pub retry_budget: f64,
    /// Duplicate a request still running `hedge_delay` seconds after
    /// its arrival floor onto a disjoint minimal route
    /// (`INFINITY` = never hedge).
    pub hedge_delay: f64,
}

impl Default for ClassPolicy {
    fn default() -> Self {
        Self::OFF
    }
}

impl ClassPolicy {
    /// The do-nothing policy: admits everything, no deadline, no
    /// budget, no hedging.
    pub const OFF: ClassPolicy = ClassPolicy {
        admit_rate: f64::INFINITY,
        admit_burst: f64::INFINITY,
        backlog_limit: u64::MAX,
        deadline: f64::INFINITY,
        retry_budget: f64::INFINITY,
        hedge_delay: f64::INFINITY,
    };

    /// True when every control is off — this entry can never shed,
    /// abandon, fail or hedge anything.
    pub fn is_off(&self) -> bool {
        self.admit_rate.is_infinite()
            && self.backlog_limit == u64::MAX
            && self.deadline.is_infinite()
            && self.retry_budget.is_infinite()
            && self.hedge_delay.is_infinite()
    }
}

/// Per-[`RpcClass`] overload-control policy for one open-loop run
/// (installed via `DesOpts::policies` / `DesSession::policies`).
/// Classes beyond `classes.len()` get [`ClassPolicy::OFF`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServicePolicy {
    /// Entry `i` governs service class `i` (the index into the
    /// scenario's RPC mix).
    pub classes: Vec<ClassPolicy>,
}

impl ServicePolicy {
    pub fn new(classes: Vec<ClassPolicy>) -> Self {
        Self { classes }
    }

    /// The same policy for `n` classes.
    pub fn uniform(n: usize, p: ClassPolicy) -> Self {
        Self { classes: vec![p; n] }
    }

    /// The policy governing `class` ([`ClassPolicy::OFF`] past the end).
    pub fn class(&self, class: u8) -> &ClassPolicy {
        self.classes.get(class as usize).unwrap_or(&ClassPolicy::OFF)
    }

    /// True when no entry can ever trigger: an inert policy is
    /// bit-identical to running with no policy installed (the executor
    /// schedules no degradation events and the admission layer never
    /// sheds — asserted by the `degrade_overhead` bench).
    pub fn is_inert(&self) -> bool {
        self.classes.iter().all(ClassPolicy::is_off)
    }

    /// Stable short name for reports: which control families any class
    /// arms, dash-joined (`"shed-deadline"`, `"hedge"`, ... or
    /// `"inert"`).
    pub fn summary(&self) -> String {
        let mut parts: Vec<&str> = Vec::new();
        let any = |f: fn(&ClassPolicy) -> bool| self.classes.iter().any(f);
        if any(|c| c.admit_rate.is_finite() || c.backlog_limit != u64::MAX) {
            parts.push("shed");
        }
        if any(|c| c.deadline.is_finite()) {
            parts.push("deadline");
        }
        if any(|c| c.retry_budget.is_finite()) {
            parts.push("budget");
        }
        if any(|c| c.hedge_delay.is_finite()) {
            parts.push("hedge");
        }
        if parts.is_empty() {
            "inert".to_string()
        } else {
            parts.join("-")
        }
    }

    /// Per-class initial retry budgets, aligned with `classes` (the
    /// mutable state the executor counts retries down from).
    pub fn retry_budgets(&self) -> Vec<f64> {
        self.classes.iter().map(|c| c.retry_budget).collect()
    }
}

/// Deterministic per-class token-bucket state for admission control.
/// Buckets start full; tokens refill linearly with *simulated* arrival
/// time (never a wall clock), so the admit/shed sequence is a pure
/// function of the arrival sequence — byte-identical across runs and
/// solver thread counts.
#[derive(Debug, Clone)]
pub struct Admission {
    tokens: Vec<f64>,
    last: Vec<f64>,
}

impl Admission {
    pub fn new(policy: &ServicePolicy) -> Self {
        Self {
            tokens: policy.classes.iter().map(|c| c.admit_burst).collect(),
            last: vec![0.0; policy.classes.len()],
        }
    }

    /// Admit or shed one class-`class` arrival at simulated time `t`
    /// with the class's current `backlog` (accepted minus retired).
    /// The backlog threshold is checked first; the token bucket only
    /// spends a token on arrivals the threshold let through.
    pub fn admit(
        &mut self,
        policy: &ServicePolicy,
        class: u8,
        t: f64,
        backlog: u64,
    ) -> bool {
        let p = policy.class(class);
        if backlog >= p.backlog_limit {
            return false;
        }
        if p.admit_rate.is_infinite() {
            return true;
        }
        let c = class as usize;
        if c >= self.tokens.len() {
            return true; // past the policy: OFF
        }
        let dt = (t - self.last[c]).max(0.0);
        self.last[c] = t;
        self.tokens[c] = (self.tokens[c] + dt * p.admit_rate).min(p.admit_burst);
        if self.tokens[c] >= 1.0 {
            self.tokens[c] -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Brownout-grade preset: shed at `backlog_limit`, abandon past
/// `deadline`, cap retries — the policy shape the brownout sweep and
/// the acceptance tests use. Hedging stays off (hedges amplify load on
/// a shared bottleneck; arm [`ClassPolicy::hedge_delay`] explicitly for
/// path-diverse traffic).
pub fn brownout_policy(
    mix: &[RpcClass],
    backlog_limit: u64,
    deadline: f64,
    retry_budget: f64,
) -> ServicePolicy {
    ServicePolicy::uniform(
        mix.len().max(1),
        ClassPolicy {
            backlog_limit,
            deadline,
            retry_budget,
            ..ClassPolicy::OFF
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_policy_is_inert_and_clamps_past_the_end() {
        let p = ServicePolicy::default();
        assert!(p.is_inert());
        assert_eq!(p.summary(), "inert");
        assert_eq!(*p.class(0), ClassPolicy::OFF);
        assert_eq!(*p.class(200), ClassPolicy::OFF);
        let q = ServicePolicy::uniform(2, ClassPolicy::OFF);
        assert!(q.is_inert());
        assert_eq!(*q.class(7), ClassPolicy::OFF, "past the end: OFF");
    }

    #[test]
    fn summary_names_armed_controls() {
        let mut p = ServicePolicy::uniform(2, ClassPolicy::OFF);
        p.classes[0].deadline = 0.5;
        p.classes[1].backlog_limit = 10;
        assert_eq!(p.summary(), "shed-deadline");
        p.classes[0].hedge_delay = 0.1;
        p.classes[1].retry_budget = 8.0;
        assert_eq!(p.summary(), "shed-deadline-budget-hedge");
        assert!(!p.is_inert());
    }

    #[test]
    fn token_bucket_sheds_above_rate_and_refills() {
        let p = ServicePolicy::uniform(
            1,
            ClassPolicy {
                admit_rate: 10.0,
                admit_burst: 2.0,
                ..ClassPolicy::OFF
            },
        );
        let mut a = Admission::new(&p);
        // burst of 2 admitted instantly, the third shed
        assert!(a.admit(&p, 0, 0.0, 0));
        assert!(a.admit(&p, 0, 0.0, 0));
        assert!(!a.admit(&p, 0, 0.0, 0));
        // 0.1 s refills one token at rate 10/s
        assert!(a.admit(&p, 0, 0.1, 0));
        assert!(!a.admit(&p, 0, 0.1, 0));
        // replay is identical (pure function of the arrival sequence)
        let mut b = Admission::new(&p);
        let seq = [0.0, 0.0, 0.0, 0.1, 0.1];
        let first: Vec<bool> =
            seq.iter().map(|&t| b.admit(&p, 0, t, 0)).collect();
        let mut c = Admission::new(&p);
        let second: Vec<bool> =
            seq.iter().map(|&t| c.admit(&p, 0, t, 0)).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn backlog_threshold_sheds_without_spending_tokens() {
        let p = ServicePolicy::uniform(
            1,
            ClassPolicy {
                admit_rate: 100.0,
                admit_burst: 1.0,
                backlog_limit: 5,
                ..ClassPolicy::OFF
            },
        );
        let mut a = Admission::new(&p);
        assert!(!a.admit(&p, 0, 0.0, 5), "at the limit: shed");
        assert!(!a.admit(&p, 0, 0.0, 9), "above the limit: shed");
        // the threshold sheds consumed no token: the bucket still admits
        assert!(a.admit(&p, 0, 0.0, 0));
    }

    #[test]
    fn inert_admission_admits_everything() {
        let p = ServicePolicy::uniform(3, ClassPolicy::OFF);
        let mut a = Admission::new(&p);
        for i in 0..100u32 {
            assert!(a.admit(&p, (i % 3) as u8, i as f64, i as u64));
        }
    }

    #[test]
    fn brownout_preset_arms_shed_deadline_budget() {
        let mix = [
            RpcClass { bytes: 4096, weight: 0.7 },
            RpcClass { bytes: 65536, weight: 0.3 },
        ];
        let p = brownout_policy(&mix, 64, 0.25, 100.0);
        assert_eq!(p.classes.len(), 2);
        assert_eq!(p.summary(), "shed-deadline-budget");
        assert_eq!(p.class(0).backlog_limit, 64);
        assert_eq!(p.class(1).deadline, 0.25);
        assert_eq!(p.retry_budgets(), vec![100.0, 100.0]);
        assert!(p.class(0).hedge_delay.is_infinite(), "hedging stays off");
    }
}
