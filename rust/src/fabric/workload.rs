//! Dependency-driven (closed-loop) workloads for the DES.
//!
//! The paper's end-to-end results are *closed-loop*: each communication
//! round of a collective or application step starts only when its
//! predecessors finish, so congestion in one round delays every later
//! round (GPCNet Fig 5, the Fig 14 collective crossover, the §6 app
//! scaling studies). [`DagWorkload`] captures that structure: a DAG of
//! per-rank `Compute` intervals and fabric `Xfer`s where a node is
//! *released* by the completion of its predecessors rather than by a
//! pre-computed timestamp. Open-loop traffic is the degenerate case — a
//! root node with a `start` time and no dependencies — so congestor
//! mixes and multi-job phase interference compose freely with round
//! DAGs.
//!
//! Execution lives in [`DesSim::run_dag`](super::des::DesSim::run_dag)
//! (incremental component re-solve) and
//! [`DesSim::run_dag_oracle`](super::des::DesSim::run_dag_oracle) (full
//! re-solve per event); `tests/des_equivalence.rs` sweeps both over
//! closed-loop workloads. [`DagWorkload::critical_path`] is the
//! contention-free reference the closed-loop scenarios are compared
//! against: the analytic tier's dependency-aware prediction, which by
//! construction cannot see queueing-induced round slowdowns.

use super::rounds::CostModel;
use super::{Flow, RoutedFlow, Router};
use crate::topology::Topology;
use rustc_hash::FxHashMap;

/// What a DAG node does once released.
#[derive(Debug, Clone)]
pub enum DagKind {
    /// A fixed-duration interval on one rank (compute, intra-node copy).
    Compute(f64),
    /// A fabric transfer; completes when the DES finishes the flow
    /// (including its zero-load latency and entry queueing delay, so
    /// latency-bound dependency chains are priced correctly).
    Xfer(RoutedFlow),
}

/// One node of a dependency workload.
#[derive(Debug, Clone)]
pub struct DagNode {
    pub kind: DagKind,
    /// Predecessor node ids; the node is released when all are done.
    pub deps: Vec<u32>,
    /// Earliest absolute release time (0 for purely dependency-released
    /// nodes; the arrival time for open-loop roots).
    pub start: f64,
}

/// A dependency-released workload: nodes are added in topological order
/// (every dependency must refer to an already-added node), so the graph
/// is acyclic by construction.
#[derive(Debug, Clone, Default)]
pub struct DagWorkload {
    pub nodes: Vec<DagNode>,
}

impl DagWorkload {
    pub fn new() -> Self {
        Self { nodes: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node; `deps` must name already-added nodes (acyclicity by
    /// construction). Returns the new node's id.
    pub fn push(&mut self, kind: DagKind, deps: Vec<u32>, start: f64) -> u32 {
        let id = self.nodes.len() as u32;
        for &d in &deps {
            assert!(d < id, "dependency {d} of node {id} not yet added");
        }
        self.nodes.push(DagNode { kind, deps, start });
        id
    }

    /// Dependency-released fabric transfer.
    pub fn xfer(&mut self, rf: RoutedFlow, deps: Vec<u32>) -> u32 {
        self.push(DagKind::Xfer(rf), deps, 0.0)
    }

    /// Open-loop root transfer arriving at absolute time `start`.
    pub fn xfer_at(&mut self, rf: RoutedFlow, start: f64) -> u32 {
        self.push(DagKind::Xfer(rf), Vec::new(), start)
    }

    /// Dependency-released compute interval.
    pub fn compute(&mut self, dt: f64, deps: Vec<u32>) -> u32 {
        self.push(DagKind::Compute(dt), deps, 0.0)
    }

    /// Open-loop equivalent of a [`super::des::TimedFlow`] set: every
    /// flow is a root released at its start time. `run_dag` on this
    /// workload reproduces `run` on the original flows.
    pub fn from_timed(flows: &[super::des::TimedFlow]) -> Self {
        let mut wl = Self::new();
        for tf in flows {
            wl.xfer_at(tf.rf.clone(), tf.start);
        }
        wl
    }

    /// Ids of the transfer nodes, in insertion order (matches the flow
    /// order the DES result reports).
    pub fn xfer_ids(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.kind, DagKind::Xfer(_)))
            .map(|(i, _)| i)
            .collect()
    }

    /// Total bytes across all transfer nodes.
    pub fn total_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match &n.kind {
                DagKind::Xfer(rf) => rf.flow.bytes,
                DagKind::Compute(_) => 0,
            })
            .sum()
    }

    /// Contention-free earliest finish per node: each transfer is priced
    /// at its solo (zero-contention) time, each compute at its duration,
    /// and release times respect the dependency structure. This is what
    /// a dependency-aware *analytic* tier predicts — no max-min sharing,
    /// no incast back-pressure, no entry queueing — so the gap between
    /// `run_dag().makespan` and `critical_path().max` is exactly the
    /// congestion-induced slowdown closed-loop execution exposes.
    pub fn critical_path(&self, cm: &CostModel) -> Vec<f64> {
        let mut finish = vec![0.0f64; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            let released = node
                .deps
                .iter()
                .map(|&d| finish[d as usize])
                .fold(node.start, f64::max);
            let dur = match &node.kind {
                DagKind::Compute(dt) => dt.max(0.0),
                DagKind::Xfer(rf) => {
                    cm.solo_msg_time(&rf.path, rf.flow.bytes, rf.flow.buf)
                }
            };
            finish[i] = released + dur;
        }
        finish
    }

    /// Max over [`Self::critical_path`] — the contention-free makespan.
    pub fn critical_path_makespan(&self, cm: &CostModel) -> f64 {
        self.critical_path(cm).iter().cloned().fold(0.0, f64::max)
    }
}

/// Incrementally builds round-structured DAGs over logical endpoint keys
/// (raw NIC ids for campaign workloads, rank ids for the MPI layer).
///
/// Per-key *frontier* tracking encodes the paper's round semantics: a
/// message in round k is released once every round-(k-1) node touching
/// its **source** key is done — the sender must have finished both its
/// previous send and the receives it folds in — while the destination
/// key's frontier gains the new node so *its* next-round send waits for
/// this delivery. Rounds are committed with [`DagBuilder::end_round`];
/// within a round all messages see the pre-round frontier, so a round's
/// messages are mutually concurrent.
#[derive(Debug, Default)]
pub struct DagBuilder {
    dag: DagWorkload,
    frontier: FxHashMap<u32, Vec<u32>>,
    staged: Vec<(u32, u32)>,
}

impl DagBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// A transfer from key `a` to key `b`, released when `a`'s previous
    /// round completes. Takes effect on the frontiers at `end_round`.
    pub fn xfer(&mut self, a: u32, b: u32, rf: RoutedFlow) -> u32 {
        let deps = self.frontier.get(&a).cloned().unwrap_or_default();
        let id = self.dag.xfer(rf, deps);
        self.staged.push((a, id));
        self.staged.push((b, id));
        id
    }

    /// Commit the staged round: every key touched this round replaces its
    /// frontier with this round's nodes.
    pub fn end_round(&mut self) {
        let mut fresh: FxHashMap<u32, Vec<u32>> = FxHashMap::default();
        for &(k, id) in &self.staged {
            fresh.entry(k).or_default().push(id);
        }
        for (k, ids) in fresh {
            self.frontier.insert(k, ids);
        }
        self.staged.clear();
    }

    /// A compute interval on key `a`, serialized after everything `a` has
    /// done so far; `a`'s frontier becomes this node immediately.
    pub fn compute(&mut self, a: u32, dt: f64) -> u32 {
        let deps = self.frontier.get(&a).cloned().unwrap_or_default();
        let id = self.dag.compute(dt, deps);
        self.frontier.insert(a, vec![id]);
        id
    }

    /// A fixed-duration transfer that never touches the fabric (an
    /// intra-node message between keys `a` and `b`): released when `a`'s
    /// previous round completes, and — like [`DagBuilder::xfer`] — both
    /// keys' frontiers gain the node at `end_round`, so it participates
    /// in round dependency semantics exactly like a fabric message.
    pub fn compute_staged(&mut self, a: u32, b: u32, dt: f64) -> u32 {
        let deps = self.frontier.get(&a).cloned().unwrap_or_default();
        let id = self.dag.compute(dt, deps);
        self.staged.push((a, id));
        self.staged.push((b, id));
        id
    }

    /// Open-loop background flow (congestor, other-job traffic): a root
    /// released at absolute `start`, outside every frontier.
    pub fn open_xfer(&mut self, rf: RoutedFlow, start: f64) -> u32 {
        self.dag.xfer_at(rf, start)
    }

    /// Set the absolute release floor of an already-added node (job phase
    /// offsets, per-rank clock floors for `World::exchange` supersteps).
    /// The node still waits for its dependencies; the floor only keeps it
    /// from starting earlier.
    pub fn set_floor(&mut self, id: u32, start: f64) {
        self.dag.nodes[id as usize].start = start;
    }

    /// Nodes added so far.
    pub fn node_count(&self) -> usize {
        self.dag.len()
    }

    pub fn finish(mut self) -> DagWorkload {
        self.end_round();
        self.dag
    }
}

// ------------------------------------------------------ streaming rounds

/// One message of a streamed round (see [`RoundSource`]): either a
/// fabric transfer between two logical endpoint keys or a fixed-duration
/// node (intra-node message / compute) participating in the same round
/// dependency semantics. `start` is the node's absolute release floor
/// (0.0 for purely dependency-released traffic; per-rank clock floors
/// for `World` superstep flushes) — the node still waits for its
/// dependencies, the floor only keeps it from starting earlier,
/// mirroring [`DagBuilder::set_floor`].
#[derive(Debug, Clone)]
pub enum StreamNode {
    /// Fixed-duration node between keys `a` and `b` (use `a == b` for a
    /// pure per-key compute interval).
    Compute { a: u32, b: u32, dt: f64, start: f64 },
    /// Routed fabric transfer from key `a` to key `b`.
    Xfer { a: u32, b: u32, rf: RoutedFlow, start: f64 },
}

/// Sentinel stream-node key meaning "no frontier participation": a node
/// whose `a` (and `b`) key is `NO_KEY` takes no dependencies from the
/// previous round and registers nothing in the frontier, so it is
/// released purely by its `start` floor. Open-loop arrival traffic
/// ([`super::arrivals`]) uses this — arrivals are ordered by wall-clock,
/// not by round dependency — and rounds made only of `NO_KEY` nodes
/// retire the moment their last node completes (zero frontier refs),
/// which is what keeps live state bounded over million-arrival traces.
pub const NO_KEY: u32 = u32::MAX;

/// Lazily yields the successive rounds of a round-structured closed-loop
/// workload for [`DesSim::run_stream`](super::des::DesSim::run_stream).
/// Round `k`'s messages are released by round `k-1` per source key
/// ([`DagBuilder`] frontier semantics) without the O(rounds x P) DAG
/// ever being materialized at once. Any `FnMut() -> Option<Vec<StreamNode>>`
/// closure is a source.
pub trait RoundSource {
    /// The next round's messages; `None` once the workload is exhausted.
    /// Empty rounds are skipped by the executor.
    fn next_round(&mut self) -> Option<Vec<StreamNode>>;

    /// Earliest simulated time at which the *next* round may be
    /// materialized. The default (`0.0`) means "whenever dependencies
    /// allow" — the closed-loop behavior, where rounds materialize as
    /// the frontier releases them. Open-loop sources return the next
    /// arrival window's start time so the executor defers
    /// materialization until the clock gets there instead of pulling
    /// the whole trace up front (bounded memory at any trace length).
    /// Must be non-decreasing across calls; the executor re-queries it
    /// after every `next_round`.
    fn next_round_not_before(&mut self) -> f64 {
        0.0
    }

    /// Service class of node `i` of the round most recently returned by
    /// [`Self::next_round`] — the degradation layer's per-class policy
    /// lookup (`fabric::degrade`). Closed-loop sources keep the
    /// default: everything is class 0. Queried only while a
    /// `ServicePolicy` is armed.
    fn node_class(&self, _i: usize) -> u8 {
        0
    }
}

impl<F: FnMut() -> Option<Vec<StreamNode>>> RoundSource for F {
    fn next_round(&mut self) -> Option<Vec<StreamNode>> {
        self()
    }
}

/// Drain a round source into a fully materialized [`DagWorkload`] (the
/// equivalence reference for the streaming executor: `run_dag` on the
/// collected DAG must match `run_stream` on an identical source).
pub fn collect_rounds(src: &mut dyn RoundSource) -> DagWorkload {
    let mut b = DagBuilder::new();
    while let Some(round) = src.next_round() {
        for n in round {
            match n {
                StreamNode::Compute { a, b: bb, dt, start } => {
                    let id = b.compute_staged(a, bb, dt);
                    b.set_floor(id, start);
                }
                StreamNode::Xfer { a, b: bb, rf, start } => {
                    let id = b.xfer(a, bb, rf);
                    b.set_floor(id, start);
                }
            }
        }
        b.end_round();
    }
    b.finish()
}

/// Route `(src, dst, bytes)` round triples lazily: a [`RoundSource`]
/// that pulls round `k` from `gen` and routes its messages on demand —
/// the streaming analogue of [`dag_from_rounds`].
pub fn routed_round_source<'r, 't: 'r, G>(
    router: &'r mut Router<'t>,
    mut gen: G,
) -> impl RoundSource + 'r
where
    G: FnMut(usize) -> Option<Vec<(u32, u32, u64)>> + 'r,
{
    let mut k = 0usize;
    move || -> Option<Vec<StreamNode>> {
        let triples = gen(k)?;
        k += 1;
        Some(
            triples
                .into_iter()
                .map(|(s, d, bytes)| {
                    let f = Flow::new(s, d, bytes);
                    let path = router.route(&f);
                    StreamNode::Xfer {
                        a: s,
                        b: d,
                        rf: RoutedFlow { flow: f, path },
                        start: 0.0,
                    }
                })
                .collect(),
        )
    }
}

// ------------------------------------------------------ round generators

/// Evenly spread `ranks` logical endpoints over the fabric's NICs.
///
/// Endpoints are distinct by construction: requesting more ranks than
/// the fabric has compute endpoints would clamp the stride to 1 and wrap
/// `i * stride` around `% nics`, aliasing endpoints — and aliased
/// endpoints turn ring/pairwise generators' messages into self-flows
/// (src == dst) that the round DAGs silently drop into the frontier, so
/// this asserts instead of producing a corrupt workload.
pub fn spread_nics(topo: &Topology, ranks: usize) -> Vec<u32> {
    let nics = topo.cfg.compute_endpoints() as u64;
    assert!(
        ranks as u64 <= nics,
        "spread_nics: {ranks} ranks > {nics} compute endpoints would alias \
         endpoints (self-flows in round generators); use a larger topology \
         or fewer ranks"
    );
    let stride = (nics / ranks.max(1) as u64).max(1);
    (0..ranks as u64).map(|i| ((i * stride) % nics) as u32).collect()
}

/// `groups` blocks of `per_group` endpoints, each block confined to one
/// dragonfly group (block `g` strides through group `g`'s endpoint
/// range). Intra-block traffic therefore only touches group-local links
/// — NIC up/down plus `Local` switch links of that group — so the
/// blocks are link-disjoint by construction and the DES solves them as
/// independent components (the multi-group shape the component-parallel
/// batch solve fans out over; EXPERIMENTS.md §Parallel solve).
pub fn group_blocks(
    topo: &Topology,
    groups: usize,
    per_group: usize,
) -> Vec<Vec<u32>> {
    let epg = topo.cfg.endpoints_per_group();
    assert!(
        groups <= topo.cfg.compute_groups,
        "group_blocks: {groups} blocks > {} compute groups",
        topo.cfg.compute_groups
    );
    assert!(
        (2..=epg).contains(&per_group),
        "group_blocks: {per_group} ranks/group outside 2..={epg}"
    );
    let stride = (epg / per_group).max(1);
    (0..groups)
        .map(|g| {
            (0..per_group)
                .map(|r| (g * epg + r * stride) as u32)
                .collect()
        })
        .collect()
}

/// The multi-group "halo + allreduce" application-step rounds:
/// `halo_rounds` rounds of ±1 neighbour exchange *within* each block
/// (group-local, link-disjoint across blocks), then `leader_rounds`
/// chunked ring-allreduce rounds over the block leaders (`block[0]`),
/// which fuse the groups through global links. Every endpoint is
/// touched in every halo round and every leader in every leader round,
/// so the rounds stream exactly (`late_releases == 0`) through
/// [`super::des::DesSim::run_stream`].
pub fn halo_allreduce_rounds(
    blocks: &[Vec<u32>],
    halo_rounds: usize,
    halo_bytes: u64,
    leader_rounds: usize,
    leader_bytes: u64,
) -> Vec<Vec<(u32, u32, u64)>> {
    assert!(blocks.len() >= 2, "halo_allreduce_rounds: need >= 2 blocks");
    let mut rounds = Vec::with_capacity(halo_rounds + leader_rounds);
    for _ in 0..halo_rounds {
        let mut round =
            Vec::with_capacity(blocks.iter().map(|b| 2 * b.len()).sum());
        for b in blocks {
            round.extend(neighbor_round(b, &[-1, 1], halo_bytes));
        }
        rounds.push(round);
    }
    let leaders: Vec<u32> = blocks.iter().map(|b| b[0]).collect();
    rounds.extend(ring_rounds(&leaders, leader_rounds, leader_bytes));
    rounds
}

/// `rounds` ring rounds: in each, endpoint i sends `bytes` to i+1.
pub fn ring_rounds(
    nics: &[u32],
    rounds: usize,
    bytes: u64,
) -> Vec<Vec<(u32, u32, u64)>> {
    let p = nics.len();
    if p < 2 {
        return Vec::new();
    }
    (0..rounds)
        .map(|_| {
            (0..p).map(|i| (nics[i], nics[(i + 1) % p], bytes)).collect()
        })
        .collect()
}

/// Pairwise-exchange all-to-all: p-1 rotation rounds of `bytes` per pair.
pub fn pairwise_rounds(nics: &[u32], bytes: u64) -> Vec<Vec<(u32, u32, u64)>> {
    let p = nics.len();
    if p < 2 {
        return Vec::new();
    }
    (1..p)
        .map(|shift| {
            (0..p)
                .map(|i| (nics[i], nics[(i + shift) % p], bytes))
                .collect()
        })
        .collect()
}

/// Recursive-doubling rounds over the largest power-of-two prefix.
pub fn doubling_rounds(nics: &[u32], bytes: u64) -> Vec<Vec<(u32, u32, u64)>> {
    let mut p2 = 1usize;
    while p2 * 2 <= nics.len() {
        p2 *= 2;
    }
    let mut rounds = Vec::new();
    let mut dist = 1usize;
    while dist < p2 {
        rounds.push(
            (0..p2).map(|i| (nics[i], nics[i ^ dist], bytes)).collect(),
        );
        dist *= 2;
    }
    rounds
}

/// One halo round: every endpoint sends `bytes` to each signed-offset
/// neighbour (periodic in the endpoint list) — the 1-D embedding of a
/// stencil face exchange.
pub fn neighbor_round(
    nics: &[u32],
    offsets: &[i64],
    bytes: u64,
) -> Vec<(u32, u32, u64)> {
    let p = nics.len() as i64;
    if p < 2 {
        return Vec::new();
    }
    let mut msgs = Vec::new();
    for (i, &src) in nics.iter().enumerate() {
        for &off in offsets {
            let j = (i as i64 + off).rem_euclid(p) as usize;
            if nics[j] != src {
                msgs.push((src, nics[j], bytes));
            }
        }
    }
    msgs
}

/// Route round triples into `b`: round k is dependency-released by
/// round k-1 per source endpoint. `start` is the release floor of the
/// first pushed round (job phase offset).
pub fn push_rounds(
    b: &mut DagBuilder,
    router: &mut Router,
    rounds: &[Vec<(u32, u32, u64)>],
    start: f64,
) {
    for (k, round) in rounds.iter().enumerate() {
        for &(s, d, bytes) in round {
            let f = Flow::new(s, d, bytes);
            let path = router.route(&f);
            let id = b.xfer(s, d, RoutedFlow { flow: f, path });
            if k == 0 && start > 0.0 {
                b.dag.nodes[id as usize].start = start;
            }
        }
        b.end_round();
    }
}

/// Route round triples and assemble the closed-loop DAG (a fresh
/// [`DagBuilder`] around [`push_rounds`]).
pub fn dag_from_rounds(
    router: &mut Router,
    rounds: &[Vec<(u32, u32, u64)>],
    start: f64,
) -> DagWorkload {
    let mut b = DagBuilder::new();
    push_rounds(&mut b, router, rounds, start);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::fabric::des::{DesOpts, DesSim, TimedFlow};

    fn setup() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn push_rejects_forward_deps() {
        let t = setup();
        let mut r = Router::new(&t);
        let f = Flow::new(0, 200, 1 << 20);
        let rf = RoutedFlow { path: r.route(&f), flow: f };
        let mut wl = DagWorkload::new();
        let a = wl.xfer(rf.clone(), vec![]);
        let b = wl.xfer(rf, vec![a]);
        assert_eq!((a, b), (0, 1));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let mut wl2 = wl.clone();
                wl2.compute(1.0, vec![99]);
            },
        ));
        assert!(res.is_err(), "forward dependency must be rejected");
    }

    #[test]
    fn chain_serializes_transfers() {
        // fabric-disjoint flows: chained they serialize (~2x), as
        // concurrent roots they overlap (~1x)
        let t = setup();
        let mut r = Router::new(&t);
        let mk = |r: &mut Router, src: u32, dst: u32| {
            let f = Flow::new(src, dst, 16 << 20);
            RoutedFlow { path: r.route(&f), flow: f }
        };
        let mut chain = DagWorkload::new();
        let a = chain.xfer(mk(&mut r, 0, 200), vec![]);
        chain.xfer(mk(&mut r, 8, 208), vec![a]);
        let mut flat = DagWorkload::new();
        flat.xfer(mk(&mut r, 0, 200), vec![]);
        flat.xfer(mk(&mut r, 8, 208), vec![]);
        let sim = DesSim::new(&t, DesOpts::default());
        let tc = sim.run_dag(&chain).makespan;
        let tf = sim.run_dag(&flat).makespan;
        assert!(tc > tf * 1.5, "chain {tc} vs flat {tf}");
    }

    #[test]
    fn compute_delays_released_transfer() {
        let t = setup();
        let mut r = Router::new(&t);
        let f = Flow::new(0, 200, 1 << 20);
        let rf = RoutedFlow { path: r.route(&f), flow: f };
        let mut wl = DagWorkload::new();
        let c = wl.compute(0.5, vec![]);
        wl.xfer(rf, vec![c]);
        let res = DesSim::new(&t, DesOpts::default()).run_dag(&wl);
        assert!((res.node_finish[0] - 0.5).abs() < 1e-12);
        assert!(res.node_finish[1] > 0.5);
    }

    #[test]
    fn from_timed_matches_open_loop_run() {
        let t = setup();
        let mut r = Router::new(&t);
        let timed: Vec<TimedFlow> = (0..10)
            .map(|i| {
                let f = Flow::new(i * 4, 200 + i, (1 + i as u64) << 20);
                TimedFlow {
                    rf: RoutedFlow { path: r.route(&f), flow: f },
                    start: (i % 3) as f64 * 1e-3,
                }
            })
            .collect();
        let sim = DesSim::new(&t, DesOpts::default());
        let open = sim.run(&timed);
        let dag = sim.run_dag(&DagWorkload::from_timed(&timed));
        for (i, (a, b)) in
            open.finish.iter().zip(&dag.node_finish).enumerate()
        {
            let rel = (a - b).abs() / b.abs().max(1e-30);
            assert!(rel < 1e-9, "flow {i}: open {a} vs dag {b}");
        }
    }

    #[test]
    fn critical_path_respects_deps_and_start() {
        let t = setup();
        let cm = CostModel::new(&t);
        let mut r = Router::new(&t);
        let f = Flow::new(0, 200, 4 << 20);
        let rf = RoutedFlow { path: r.route(&f), flow: f };
        let mut wl = DagWorkload::new();
        let a = wl.xfer_at(rf.clone(), 1.0);
        let b = wl.compute(0.25, vec![a]);
        wl.xfer(rf.clone(), vec![b]);
        let cp = wl.critical_path(&cm);
        let solo = cm.solo_msg_time(&rf.path, rf.flow.bytes, rf.flow.buf);
        assert!((cp[0] - (1.0 + solo)).abs() < 1e-12);
        assert!((cp[1] - (1.0 + solo + 0.25)).abs() < 1e-12);
        assert!((cp[2] - (1.0 + 2.0 * solo + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn spread_nics_rejects_aliasing_and_stays_distinct() {
        // regression (tiny topology): ranks > compute_endpoints used to
        // clamp the stride to 1 and wrap, aliasing endpoints into
        // self-flows; it must assert instead
        let t = Topology::new(&AuroraConfig::tiny()); // 64 endpoints
        let n = t.cfg.compute_endpoints();
        let nics = spread_nics(&t, n);
        let mut uniq = nics.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), n, "full-fabric spread must stay distinct");
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || spread_nics(&t, n + 1),
        ));
        assert!(res.is_err(), "oversubscribed spread must be rejected");
    }

    #[test]
    fn group_blocks_are_group_confined_and_distinct() {
        let t = setup();
        let blocks = group_blocks(&t, 3, 8);
        assert_eq!(blocks.len(), 3);
        let mut all: Vec<u32> = Vec::new();
        for (g, b) in blocks.iter().enumerate() {
            assert_eq!(b.len(), 8);
            for &nic in b {
                assert_eq!(
                    t.group_of_node(t.node_of_nic(nic)),
                    g as u16,
                    "block {g} endpoint {nic} strays outside its group"
                );
            }
            all.extend(b);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 24, "blocks must not alias endpoints");
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || group_blocks(&t, 99, 8),
        ));
        assert!(res.is_err(), "more blocks than groups must be rejected");
    }

    #[test]
    fn halo_allreduce_rounds_shape_and_streaming_exactness() {
        let t = setup();
        let blocks = group_blocks(&t, 3, 8);
        let rounds = halo_allreduce_rounds(&blocks, 2, 1 << 16, 3, 1 << 16);
        assert_eq!(rounds.len(), 5);
        // halo rounds: 2 msgs per endpoint per round, group-local
        for round in &rounds[..2] {
            assert_eq!(round.len(), 3 * 8 * 2);
            for &(s, d, _) in round {
                assert_eq!(
                    t.group_of_node(t.node_of_nic(s)),
                    t.group_of_node(t.node_of_nic(d)),
                    "halo message {s}->{d} crosses groups"
                );
            }
        }
        // leader rounds: one msg per block leader
        for round in &rounds[2..] {
            assert_eq!(round.len(), 3);
        }
        // the full round structure streams exactly
        let sim = crate::fabric::des::DesSim::new(&t, DesOpts::default());
        let mut r1 = Router::with_seed(&t, 3);
        let dag = dag_from_rounds(&mut r1, &rounds, 0.0);
        let full = sim.run_dag(&dag);
        let mut r2 = Router::with_seed(&t, 3);
        let rv = rounds.clone();
        let mut src =
            routed_round_source(&mut r2, move |k| rv.get(k).cloned());
        let streamed = sim.run_stream(&mut src);
        assert_eq!(streamed.late_releases, 0);
        assert_eq!(streamed.total_nodes, dag.len());
        let rel = (streamed.makespan - full.makespan).abs()
            / full.makespan.max(1e-30);
        assert!(rel < 1e-9, "streamed vs materialized halo+allreduce");
        // halo batches must expose multi-component parallelism
        assert!(
            full.components_solved > full.solve_batches,
            "disjoint group blocks must yield multi-component batches \
             ({} components over {} batches)",
            full.components_solved,
            full.solve_batches
        );
    }

    #[test]
    fn collect_rounds_matches_dag_from_rounds() {
        // the streaming source adapter and the materializing builder
        // must express identical round DAGs
        let t = setup();
        let nics = spread_nics(&t, 6);
        let rr = ring_rounds(&nics, 3, 2048);
        let mut r1 = Router::with_seed(&t, 5);
        let via_builder = dag_from_rounds(&mut r1, &rr, 0.0);
        let mut r2 = Router::with_seed(&t, 5);
        let rr2 = rr.clone();
        let mut src = routed_round_source(&mut r2, move |k| {
            rr2.get(k).cloned()
        });
        let via_source = collect_rounds(&mut src);
        assert_eq!(via_builder.len(), via_source.len());
        for (a, b) in via_builder.nodes.iter().zip(&via_source.nodes) {
            assert_eq!(a.deps, b.deps);
            match (&a.kind, &b.kind) {
                (DagKind::Xfer(x), DagKind::Xfer(y)) => {
                    assert_eq!(x.path, y.path);
                    assert_eq!(x.flow.bytes, y.flow.bytes);
                }
                _ => panic!("kind mismatch"),
            }
        }
    }

    #[test]
    fn run_stream_matches_run_dag_on_ring() {
        let t = setup();
        let nics = spread_nics(&t, 8);
        let rr = ring_rounds(&nics, 4, 1 << 20);
        let mut r1 = Router::with_seed(&t, 9);
        let dag = dag_from_rounds(&mut r1, &rr, 0.0);
        let sim = DesSim::new(&t, DesOpts::default());
        let full = sim.run_dag(&dag);
        let mut r2 = Router::with_seed(&t, 9);
        let rr2 = rr.clone();
        let mut src = routed_round_source(&mut r2, move |k| {
            rr2.get(k).cloned()
        });
        let streamed = sim.run_stream(&mut src);
        let rel = (full.makespan - streamed.makespan).abs()
            / full.makespan.max(1e-30);
        assert!(
            rel < 1e-9,
            "streamed {} vs materialized {}",
            streamed.makespan,
            full.makespan
        );
        assert_eq!(streamed.late_releases, 0);
        assert_eq!(streamed.total_nodes, dag.len());
        assert!(streamed.peak_live_nodes <= dag.len());
    }

    #[test]
    fn set_floor_delays_release() {
        let t = setup();
        let mut r = Router::new(&t);
        let f = Flow::new(0, 200, 1 << 20);
        let rf = RoutedFlow { path: r.route(&f), flow: f };
        let mut b = DagBuilder::new();
        let id = b.xfer(0, 1, rf);
        b.set_floor(id, 2.5);
        assert_eq!(b.node_count(), 1);
        let wl = b.finish();
        let res = DesSim::new(&t, DesOpts::default()).run_dag(&wl);
        assert!(res.node_finish[0] > 2.5, "floor must gate the transfer");
    }

    #[test]
    fn round_generators_shapes() {
        let t = setup();
        let nics = spread_nics(&t, 8);
        assert_eq!(nics.len(), 8);
        assert_eq!(ring_rounds(&nics, 3, 1024).len(), 3);
        assert_eq!(pairwise_rounds(&nics, 1024).len(), 7);
        assert_eq!(doubling_rounds(&nics, 1024).len(), 3);
        let halo = neighbor_round(&nics, &[-1, 1, 2], 1024);
        assert_eq!(halo.len(), 24);
        // ring DAG: round-k send depends on the sender's round-(k-1) pair
        let mut r = Router::new(&t);
        let wl = dag_from_rounds(&mut r, &ring_rounds(&nics, 2, 1024), 0.0);
        assert_eq!(wl.len(), 16);
        // node 8 is endpoint 0's round-1 send; deps must be its round-0
        // send (id 0) and its round-0 receive (id 7, from endpoint 7)
        let mut deps = wl.nodes[8].deps.clone();
        deps.sort_unstable();
        assert_eq!(deps, vec![0, 7]);
    }
}
