//! Adaptive routing (paper §3.1, §4.2.1).
//!
//! UGAL-style decisions: each flow scores all minimal candidates (one per
//! parallel global link) against the current link loads; if the best
//! minimal path is congested past a threshold, Valiant non-minimal
//! candidates through intermediate groups are considered with a bias
//! multiplier. With the §4.2.1 *group load setting* enabled the
//! intermediate group is the least-loaded candidate rather than a
//! probabilistic pick. Ordered traffic (MPI envelopes) pins its decision
//! per destination while traffic is pending (§3.1).

use super::{Flow, LoadMap, TrafficClass};
use crate::topology::{LinkId, Path, Topology};
use crate::util::Pcg;
use rustc_hash::{FxHashMap, FxHashSet};

/// Key of one route-cache entry: repeated-structure traffic (collective
/// rings, app halo loops) re-sends the same (src, dst) pair with the same
/// class for O(P) rounds, so the decision is memoized per pair. `ordered`
/// is part of the key so an unordered entry can never shadow the pinned
/// (ordered) machinery, which keeps its own map and idle semantics.
type RouteKey = (u32, u32, TrafficClass, bool);

/// Opt-in memo of routing *decisions* for unordered traffic (see
/// [`Router::enable_route_cache`]). A hit replays the first decision for
/// the key and still commits the flow's load — the same replay-and-commit
/// contract ordered (pinned-route) traffic has always had, extended to
/// the repeated-structure round generators, minus the
/// [`Router::destination_idle`] re-decision trigger (unordered traffic
/// has no pending-to-destination bookkeeping to clear).
#[derive(Debug, Default)]
struct RouteCache {
    map: FxHashMap<RouteKey, Path>,
    hits: usize,
}

pub struct Router<'t> {
    pub topo: &'t Topology,
    /// Normalized (seconds-of-service) load per link, updated as flows are
    /// routed; the adaptive-routing input.
    pub loads: LoadMap,
    /// Pinned routes for ordered traffic: (src, dst) -> chosen path.
    pinned: FxHashMap<(u32, u32), Path>,
    /// Route memo for unordered repeated-structure traffic (None = off).
    cache: Option<RouteCache>,
    /// §3.4 lane-degraded links: bandwidth multiplier per link, the same
    /// map the DES prices ([`crate::fabric::des::DesOpts::degraded`]).
    /// Candidate scoring divides by *effective* bandwidth so adaptive
    /// decisions route around degraded links the way real UGAL does.
    degraded: FxHashMap<LinkId, f64>,
    rng: Pcg,
    /// Statistics: how many flows were diverted non-minimally.
    pub nonminimal_count: usize,
    pub total_routed: usize,
    /// Full adaptive decisions made (excludes pinned replays and route-
    /// cache hits) — the machine-independent numerator/denominator of the
    /// `des_route_cache_*` bench ratio.
    pub decisions: usize,
    /// Fallback flag: when set, [`Router::set_degraded`] drops *every*
    /// stored decision (the pre-scoped behaviour) instead of only the
    /// decisions whose path crosses a changed link. Scoped invalidation
    /// is the default; flip this when an experiment needs every pair to
    /// re-decide against the new fabric (e.g. to re-balance around a
    /// recovered link that untouched paths would otherwise ignore until
    /// their own next re-decision).
    pub full_flush: bool,
}

impl<'t> Router<'t> {
    pub fn new(topo: &'t Topology) -> Self {
        Self::with_seed(topo, 0x5ee5)
    }

    pub fn with_seed(topo: &'t Topology, seed: u64) -> Self {
        Self {
            topo,
            loads: LoadMap::new(topo),
            pinned: FxHashMap::default(),
            cache: None,
            degraded: FxHashMap::default(),
            rng: Pcg::new(seed),
            nonminimal_count: 0,
            total_routed: 0,
            decisions: 0,
            full_flush: false,
        }
    }

    /// Install the §3.4 degraded-link multipliers (replacing any previous
    /// set) and invalidate the stored decisions the change can actually
    /// stale: the route cache and the pinned-route map hold *paths only*,
    /// so a decision whose path crosses a link whose effective multiplier
    /// changed must not replay against the new bandwidths. Decisions on
    /// untouched paths keep both their cache entry and their pin (their
    /// own service times are unchanged; they re-score alternatives at
    /// their next natural re-decision) — set [`Router::full_flush`] to
    /// restore the drop-everything behaviour. Pass the same map as
    /// [`crate::fabric::des::DesOpts::degraded`] so routing and DES
    /// pricing see one fabric.
    pub fn set_degraded<I>(&mut self, degraded: I)
    where
        I: IntoIterator<Item = (LinkId, f64)>,
    {
        let new: FxHashMap<LinkId, f64> = degraded.into_iter().collect();
        if self.full_flush {
            self.degraded = new;
            self.pinned.clear();
            if let Some(c) = &mut self.cache {
                c.map.clear(); // keep the hit counter: it counts history
            }
            return;
        }
        // effective multiplier defaults to 1.0 on both sides, so an
        // entry appearing or vanishing only counts when it moves the
        // effective value; bitwise compare keeps this exact
        let one = 1.0f64.to_bits();
        let mut changed: Vec<LinkId> = Vec::new();
        for (l, m) in &new {
            let old = self.degraded.get(l).copied().unwrap_or(1.0);
            if old.to_bits() != m.to_bits() {
                changed.push(*l);
            }
        }
        for (l, m) in &self.degraded {
            if !new.contains_key(l) && m.to_bits() != one {
                changed.push(*l);
            }
        }
        self.degraded = new;
        self.invalidate_links(changed);
    }

    /// Drop every stored decision (route-cache entry or pinned ordered
    /// route) whose path crosses one of `links`; decisions on untouched
    /// paths survive. The scoped half of [`Router::set_degraded`], public
    /// so fault injection ([`crate::fabric::faults::FaultSchedule`] via
    /// `World::inject_faults`) can invalidate exactly the routes a fault
    /// timeline touches.
    pub fn invalidate_links<I>(&mut self, links: I)
    where
        I: IntoIterator<Item = LinkId>,
    {
        let set: FxHashSet<LinkId> = links.into_iter().collect();
        if set.is_empty() {
            return;
        }
        self.pinned
            .retain(|_, p| !p.links.iter().any(|l| set.contains(l)));
        if let Some(c) = &mut self.cache {
            c.map
                .retain(|_, p| !p.links.iter().any(|l| set.contains(l)));
        }
    }

    /// Effective per-direction bandwidth: nominal scaled by the degraded
    /// multiplier. The healthy-fabric hot path stays hash-free.
    #[inline]
    fn eff_bw(&self, l: &LinkId) -> f64 {
        let base = self.topo.link_bw(l);
        if self.degraded.is_empty() {
            base
        } else {
            base * self.degraded.get(l).copied().unwrap_or(1.0)
        }
    }

    /// Turn on the route cache: unordered flows memoize their decision
    /// per (src, dst, class, ordered) and replay it (committing load) on
    /// every later call. Ordered flows are untouched — they keep the
    /// §3.1 pinned-route map with its [`Router::destination_idle`]
    /// re-decision semantics. Intended for repeated-structure workloads
    /// (ring/pairwise collective rounds, app halo loops) where the same
    /// pair is re-routed every round; see EXPERIMENTS.md §Route cache
    /// for when the cached run is byte-identical to the uncached one.
    pub fn enable_route_cache(&mut self) {
        if self.cache.is_none() {
            self.cache = Some(RouteCache::default());
        }
    }

    /// Route-cache hits so far (0 when the cache is disabled).
    pub fn route_cache_hits(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.hits)
    }

    /// Bottleneck service time (load / *effective* bw) along the
    /// *fabric* links of a path plus a small per-hop term so longer
    /// paths lose ties. Endpoint (NIC) links are excluded:
    /// injection/ejection is unavoidable, and the switch's adaptive
    /// decision only chooses among fabric routes. Degraded links divide
    /// by their reduced bandwidth — the same service time the DES
    /// charges — so equal loads no longer hide a half-bandwidth link.
    fn bottleneck(&self, path: &Path) -> f64 {
        path.links
            .iter()
            .filter(|l| !matches!(l, LinkId::NicUp(_) | LinkId::NicDown(_)))
            .map(|l| self.loads.get(l) / self.eff_bw(l))
            .fold(0.0, f64::max)
    }

    fn score(&self, path: &Path) -> f64 {
        self.bottleneck(path) + path.switch_hops as f64 * 1e-9
    }

    /// Choose a path for `flow` and account its bytes on the chosen links.
    pub fn route(&mut self, flow: &Flow) -> Path {
        self.total_routed += 1;
        let key = (flow.src_nic, flow.dst_nic);
        if flow.ordered {
            if let Some(p) = self.pinned.get(&key) {
                let p = p.clone();
                self.commit(&p, flow.bytes as f64);
                return p;
            }
        } else if let Some(c) = &mut self.cache {
            let ck = (flow.src_nic, flow.dst_nic, flow.class, flow.ordered);
            if let Some(p) = c.map.get(&ck) {
                let p = p.clone();
                c.hits += 1;
                self.commit(&p, flow.bytes as f64);
                return p;
            }
        }
        let path = self.decide(flow);
        self.commit(&path, flow.bytes as f64);
        if flow.ordered {
            self.pinned.insert(key, path.clone());
        } else if let Some(c) = &mut self.cache {
            let ck = (flow.src_nic, flow.dst_nic, flow.class, flow.ordered);
            c.map.insert(ck, path.clone());
        }
        path
    }

    /// Ordered-flow bookkeeping: "a new decision ... will be made whenever
    /// no traffic is pending to that destination" (§3.1).
    pub fn destination_idle(&mut self, src: u32, dst: u32) {
        self.pinned.remove(&(src, dst));
    }

    fn commit(&mut self, path: &Path, bytes: f64) {
        self.loads.add_path(&path.links, bytes);
    }

    fn decide(&mut self, flow: &Flow) -> Path {
        self.decisions += 1;
        let cfg = &self.topo.cfg;
        let cands = self.topo.minimal_candidates(flow.src_nic, flow.dst_nic);
        let (best_min, best_score) = cands
            .into_iter()
            .map(|p| {
                let s = self.score(&p);
                (p, s)
            })
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("at least one minimal candidate");

        // In the absence of contention all traffic routes minimally (§3.1).
        let src_g = self.topo.group_of_node(self.topo.node_of_nic(flow.src_nic));
        let dst_g = self.topo.group_of_node(self.topo.node_of_nic(flow.dst_nic));
        // congestion test compares queued *load* (not the hop tiebreak) to
        // this flow's own service time
        let congested = self.bottleneck(&best_min)
            > cfg.nonminimal_threshold * flow.bytes as f64
                / self.topo.cfg.nic_bw;
        let n_groups = cfg.compute_groups as u16;
        // Valiant needs a third group to route through
        if src_g == dst_g || !congested || n_groups < 3 {
            return best_min;
        }

        // Valiant candidates through intermediate groups.
        let mut best_nm: Option<(Path, f64)> = None;
        let tries = cfg.adaptive_candidates.max(1);
        for _ in 0..tries {
            let via = loop {
                let g = self.rng.gen_range(n_groups as u64) as u16;
                if g != src_g && g != dst_g {
                    break g;
                }
            };
            let i1 = self.rng.gen_range(cfg.global_links_compute as u64) as u8;
            let i2 = self.rng.gen_range(cfg.global_links_compute as u64) as u8;
            let p = self
                .topo
                .nonminimal_path(flow.src_nic, flow.dst_nic, via, i1, i2);
            let s = self.score(&p);
            if cfg.group_load_setting {
                // keep the least-loaded intermediate group (§4.2.1)
                if best_nm.as_ref().map_or(true, |(_, bs)| s < *bs) {
                    best_nm = Some((p, s));
                }
            } else {
                // probabilistic pick: first candidate wins
                best_nm = Some((p, s));
                break;
            }
        }
        match best_nm {
            Some((p, s)) if s * cfg.nonminimal_bias < best_score => {
                self.nonminimal_count += 1;
                p
            }
            _ => best_min,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    fn topo() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn uncontended_routes_minimally() {
        let t = topo();
        let mut r = Router::new(&t);
        let p = r.route(&Flow::new(0, 200, 1 << 20));
        assert!(p.minimal);
        assert_eq!(r.nonminimal_count, 0);
    }

    #[test]
    fn ordered_flows_pin_routes() {
        let t = topo();
        let mut r = Router::new(&t);
        let f = Flow::new(0, 200, 4096).ordered();
        let p1 = r.route(&f);
        // load the chosen path heavily; a new unordered decision would move
        for l in &p1.links {
            r.loads.add(*l, 1e12);
        }
        let p2 = r.route(&f);
        assert_eq!(p1, p2, "ordered flow must keep its route");
        r.destination_idle(0, 200);
        // after idle the decision may change (no assertion on inequality —
        // just that re-decision happens without the pin)
        let _ = r.route(&f);
    }

    #[test]
    fn pin_is_keyed_per_src_dst_pair() {
        let t = topo();
        let mut r = Router::new(&t);
        let f1 = Flow::new(0, 200, 4096).ordered();
        let p1 = r.route(&f1);
        // same source, different destination: its own pin, its own
        // decision — and it must not disturb the (0, 200) pin
        let f2 = Flow::new(0, 201, 4096).ordered();
        let p2a = r.route(&f2);
        for l in &p2a.links {
            r.loads.add(*l, 1e12);
        }
        assert_eq!(r.route(&f2), p2a, "(0,201) keeps its pin");
        assert_eq!(r.route(&f1), p1, "(0,200) pin unaffected");
        // idling one destination only clears that destination's pin
        r.destination_idle(0, 201);
        assert_eq!(r.route(&f1), p1, "(0,200) still pinned after \
                    (0,201) idles");
    }

    #[test]
    fn pinned_reroutes_do_not_inflate_nonminimal_count() {
        let t = topo();
        let mut r = Router::new(&t);
        // force persistent congestion so the first ordered decision is
        // (very likely) non-minimal, then replay the pinned route: the
        // counter must reflect *decisions*, not pinned replays
        let bulk = Flow::new(0, 200, 1 << 16);
        for _ in 0..400 {
            r.route(&bulk.clone());
        }
        let before = r.nonminimal_count;
        let ordered = Flow::new(8, 208, 1 << 16).ordered();
        let p = r.route(&ordered);
        let after_first = r.nonminimal_count;
        assert!(after_first - before <= 1, "one decision, at most one bump");
        for _ in 0..10 {
            assert_eq!(r.route(&ordered), p, "pinned while pending");
        }
        assert_eq!(
            r.nonminimal_count, after_first,
            "pinned replays must not touch nonminimal_count"
        );
        assert_eq!(r.total_routed, 400 + 11);
        // after idle, a fresh decision may bump the counter again — but
        // only by one per re-decision
        r.destination_idle(8, 208);
        let _ = r.route(&ordered);
        assert!(r.nonminimal_count - after_first <= 1);
    }

    #[test]
    fn unordered_flows_never_pin() {
        let t = topo();
        let mut r = Router::new(&t);
        let f = Flow::new(0, 200, 1 << 20);
        let p1 = r.route(&f);
        // pile load on p1: the next unordered decision is free to move
        for l in &p1.links {
            r.loads.add(*l, 1e12);
        }
        let p2 = r.route(&f);
        // no pin entry means destination_idle is a no-op for it
        r.destination_idle(0, 200);
        let p3 = r.route(&f);
        // all three must be valid src->dst paths (possibly distinct)
        for p in [&p1, &p2, &p3] {
            assert_eq!(
                p.links.first(),
                Some(&crate::topology::LinkId::NicUp(0))
            );
            assert_eq!(
                p.links.last(),
                Some(&crate::topology::LinkId::NicDown(200))
            );
        }
    }

    #[test]
    fn hotspot_diverts_nonminimally() {
        let t = topo();
        let mut r = Router::new(&t);
        // saturate both parallel global links between group 0 and group 3
        let f = Flow::new(0, 200, 1 << 16);
        for _ in 0..400 {
            r.route(&f.clone());
        }
        assert!(
            r.nonminimal_count > 0,
            "persistent congestion must trigger Valiant routing"
        );
    }

    #[test]
    fn route_cache_replays_and_still_commits_load() {
        let t = topo();
        let mut r = Router::new(&t);
        r.enable_route_cache();
        let f = Flow::new(0, 200, 1 << 20);
        let p1 = r.route(&f);
        let before = r.loads.max_on(&p1.links);
        let p2 = r.route(&f);
        assert_eq!(p1, p2, "cache hit must replay the first decision");
        assert_eq!(r.route_cache_hits(), 1);
        assert_eq!(r.decisions, 1, "one decision, one replay");
        assert!(
            r.loads.max_on(&p1.links) > before,
            "cache hits must keep committing load"
        );
    }

    #[test]
    fn route_cache_keys_on_class_and_skips_ordered() {
        use crate::fabric::TrafficClass;
        let t = topo();
        let mut r = Router::new(&t);
        r.enable_route_cache();
        let be = Flow::new(0, 200, 4096);
        let ll = Flow::new(0, 200, 4096).class(TrafficClass::LowLatency);
        r.route(&be);
        r.route(&ll);
        assert_eq!(
            r.route_cache_hits(),
            0,
            "different classes must not share an entry"
        );
        r.route(&be);
        r.route(&ll);
        assert_eq!(r.route_cache_hits(), 2);
        // ordered flows stay on the pinned-route machinery: replays are
        // pin replays (not cache hits) and destination_idle still forces
        // a fresh decision
        let ord = Flow::new(8, 208, 4096).ordered();
        r.route(&ord);
        let decided = r.decisions;
        r.route(&ord);
        assert_eq!(r.decisions, decided, "pin replay, not a re-decision");
        assert_eq!(r.route_cache_hits(), 2, "ordered flows bypass the memo");
        r.destination_idle(8, 208);
        r.route(&ord);
        assert_eq!(
            r.decisions,
            decided + 1,
            "idle must force a fresh ordered decision despite the cache"
        );
    }

    #[test]
    fn route_cache_is_exact_for_single_candidate_pairs() {
        // intra-group pairs have exactly one minimal candidate and the
        // decision short-circuits before any load comparison, so the
        // cached and uncached routers provably choose identical paths
        // round after round
        let t = topo();
        let mut plain = Router::with_seed(&t, 3);
        let mut cached = Router::with_seed(&t, 3);
        cached.enable_route_cache();
        for _round in 0..6 {
            for i in 0..8u32 {
                let f = Flow::new(i * 4, (i * 4 + 12) % 60, 1 << 20);
                assert_eq!(plain.route(&f), cached.route(&f));
            }
        }
        assert_eq!(cached.route_cache_hits(), 5 * 8);
    }

    #[test]
    fn degraded_global_link_diverts_traffic() {
        // §3.4 regression: equal loads on the parallel global links tie
        // and the first candidate wins; once that link is lane-degraded
        // to half bandwidth its service time doubles, and the decision
        // must divert — the degraded-blind router kept scoring it as
        // healthy and never moved.
        let t = topo();
        let sg = t.group_of_node(t.node_of_nic(0));
        let dg = t.group_of_node(t.node_of_nic(200));
        assert_ne!(sg, dg, "test needs an inter-group pair");
        let preload = |r: &mut Router| {
            for i in 0..t.cfg.global_links_compute as u8 {
                r.loads.add(
                    LinkId::Global { src: sg, dst: dg, idx: i },
                    1e5,
                );
            }
        };
        let f = Flow::new(0, 200, 1 << 20);
        let mut healthy = Router::with_seed(&t, 9);
        preload(&mut healthy);
        let hot = *healthy
            .route(&f)
            .links
            .iter()
            .find(|l| matches!(l, LinkId::Global { .. }))
            .expect("inter-group path crosses a global link");
        let mut deg = Router::with_seed(&t, 9);
        preload(&mut deg);
        deg.set_degraded([(hot, 0.5)]);
        let dp = deg.route(&f);
        assert!(
            !dp.links.contains(&hot),
            "traffic must route around the degraded link: {dp:?}"
        );
    }

    #[test]
    fn set_degraded_invalidation_is_scoped_to_changed_links() {
        // cache and pin store paths only: a decision whose path crosses
        // the changed link must not replay — but untouched (src,dst)
        // pairs keep their cached path and pin, and `decisions` must
        // not move for them
        let t = topo();
        let mut r = Router::new(&t);
        r.enable_route_cache();
        let f = Flow::new(0, 200, 1 << 20);
        r.route(&f);
        r.route(&f);
        assert_eq!(r.decisions, 1);
        assert_eq!(r.route_cache_hits(), 1);
        let ord = Flow::new(8, 208, 4096).ordered();
        r.route(&ord);
        r.route(&ord);
        assert_eq!(r.decisions, 2, "pin replay is not a decision");
        // degrade (0,200)'s injection link: the cached (0,200) entry
        // crosses it and must re-decide; (8,208) injects on NicUp(8)
        // and its pin must survive
        r.set_degraded([(LinkId::NicUp(0), 0.5)]);
        r.route(&f);
        assert_eq!(
            r.decisions, 3,
            "cached path crossing the changed link must re-decide"
        );
        r.route(&ord);
        assert_eq!(
            r.decisions, 3,
            "(8,208) does not cross NicUp(0): pin must replay untouched"
        );
        // the refreshed (0,200) decision memoizes again
        r.route(&f);
        assert_eq!(r.decisions, 3);
        assert_eq!(r.route_cache_hits(), 2);
        // clearing the degrade changes NicUp(0)'s effective multiplier
        // back (0.5 -> 1.0): (0,200) invalidated again, (8,208) not
        r.set_degraded([]);
        r.route(&f);
        r.route(&ord);
        assert_eq!(r.decisions, 4, "only the recovered link's pair moves");
    }

    #[test]
    fn set_degraded_full_flush_flag_restores_global_invalidation() {
        let t = topo();
        let mut r = Router::new(&t);
        r.full_flush = true;
        r.enable_route_cache();
        let f = Flow::new(0, 200, 1 << 20);
        let ord = Flow::new(8, 208, 4096).ordered();
        r.route(&f);
        r.route(&ord);
        assert_eq!(r.decisions, 2);
        r.set_degraded([(LinkId::NicUp(0), 0.5)]);
        r.route(&f);
        r.route(&ord);
        assert_eq!(r.decisions, 4, "full flush drops every stored decision");
    }

    #[test]
    fn invalidate_links_drops_only_crossing_routes() {
        let t = topo();
        let mut r = Router::new(&t);
        r.enable_route_cache();
        let f = Flow::new(0, 200, 1 << 20);
        let ord = Flow::new(8, 208, 4096).ordered();
        r.route(&f);
        r.route(&ord);
        assert_eq!(r.decisions, 2);
        r.invalidate_links([LinkId::NicUp(0)]);
        r.route(&ord);
        assert_eq!(r.decisions, 2, "(8,208) pin survives");
        r.route(&f);
        assert_eq!(r.decisions, 3, "(0,200) cache entry dropped");
        r.invalidate_links([]);
        r.route(&f);
        r.route(&ord);
        assert_eq!(r.decisions, 3, "empty set is a no-op");
    }

    #[test]
    fn load_spreads_over_parallel_links() {
        let t = topo();
        let mut r = Router::new(&t);
        // many unordered flows between the same groups should use both
        // parallel global links
        let mut used = std::collections::HashSet::new();
        for i in 0..16 {
            let p = r.route(&Flow::new(i % 8, 200 + (i % 8), 1 << 20));
            for l in &p.links {
                if let crate::topology::LinkId::Global { idx, .. } = l {
                    used.insert(*idx);
                }
            }
        }
        assert!(used.len() >= 2, "adaptive routing must spread: {used:?}");
    }
}
