//! Deterministic mid-run fault injection (EXPERIMENTS.md §Fault
//! injection).
//!
//! At Aurora's component count (~85k Cassini NICs, 5,600 Rosetta
//! switches) link flaps, degraded lanes and NIC/node failures are
//! steady-state events, not exceptions. A [`FaultSchedule`] is a
//! time-ordered list of [`FaultEvent`]s executed *inside* the DES event
//! heap (`EV_FAULT` in `fabric::des`): at fire time the effective
//! capacity of every touched link is recomputed, exactly the components
//! whose links changed are re-solved, and a [`FaultPolicy`] decides
//! what happens to in-flight flows crossing a link that went down.
//!
//! Determinism contract: the schedule is plain data (sorted `Vec`, no
//! hash iteration, no clocks); [`FaultSchedule::random_flaps`] draws
//! from its own seeded [`Pcg`] stream, so identical seeds produce
//! identical timelines on every host and the campaign byte-diff gates
//! extend to chaos scenarios unchanged. A schedule firing every event
//! at `t = 0` is bit-identical to installing the same multipliers
//! statically via `DesOpts::degraded` (pinned by
//! `tests/des_equivalence.rs`).

use crate::topology::{LinkId, Topology};
use crate::util::Pcg;

/// Dedicated Pcg stream for [`FaultSchedule::random_flaps`] — disjoint
/// from the workload (`0x5ce0`) and router (`seed ^ 0x707e`) streams.
pub const FAULT_RNG_STREAM: u64 = 0xFA17;

/// One fault. Multipliers scale the link's per-direction bandwidth
/// (§3.4 lane disable prices a degraded link the same way); `LinkDown`
/// is multiplier `0.0`, `LinkRecover` restores `1.0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Scale one link's bandwidth by `multiplier` (0.0 < m <= 1.0).
    LinkDegrade { link: LinkId, multiplier: f64 },
    /// Take one link fully down (multiplier 0.0): in-flight flows
    /// crossing it are handled by the schedule's [`FaultPolicy`].
    LinkDown { link: LinkId },
    /// Restore one link to full bandwidth (multiplier 1.0).
    LinkRecover { link: LinkId },
    /// Take one endpoint's NIC down: both its injection (`NicUp`) and
    /// ejection (`NicDown`) links go to multiplier 0.0.
    NicDown { endpoint: u32 },
    /// Take a whole node down: every NIC link of the node's
    /// `nics_per_node` endpoints goes to 0.0. Terminal — there is no
    /// `NodeRecover`; [`FaultSchedule::nodes_down_at`] treats the node
    /// as down from the fire time on.
    NodeDown { node: u32 },
}

impl FaultKind {
    /// Expand this fault into `(link, multiplier)` pairs.
    /// `nics_per_node` resolves `NodeDown` to its endpoints' NIC links.
    pub fn link_multipliers(
        &self,
        nics_per_node: usize,
        out: &mut Vec<(LinkId, f64)>,
    ) {
        match *self {
            FaultKind::LinkDegrade { link, multiplier } => {
                out.push((link, multiplier));
            }
            FaultKind::LinkDown { link } => out.push((link, 0.0)),
            FaultKind::LinkRecover { link } => out.push((link, 1.0)),
            FaultKind::NicDown { endpoint } => {
                out.push((LinkId::NicUp(endpoint), 0.0));
                out.push((LinkId::NicDown(endpoint), 0.0));
            }
            FaultKind::NodeDown { node } => {
                let base = node as usize * nics_per_node;
                for nic in base..base + nics_per_node {
                    out.push((LinkId::NicUp(nic as u32), 0.0));
                    out.push((LinkId::NicDown(nic as u32), 0.0));
                }
            }
        }
    }
}

/// A fault at a simulated time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Fire time (seconds of simulated time, `>= 0`, finite).
    pub t: f64,
    pub kind: FaultKind,
}

/// What the DES does to an in-flight flow crossing a link that went
/// down (tie-break contract: at a shared timestamp the fault applies
/// first, but a flow whose remaining bytes already reached zero during
/// the preceding interval still completes — delivered bytes are never
/// retroactively destroyed; see EXPERIMENTS.md §Fault injection).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultPolicy {
    /// Remaining bytes re-route onto the first minimal candidate path
    /// avoiding every down link (deterministic candidate order); if no
    /// such path exists the flow is marked failed.
    Reroute,
    /// The flow detaches and re-arrives after a priced timeout of
    /// `timeout * backoff^attempt`; after `max_retries` exhausted
    /// attempts it is marked failed.
    RetryBackoff { timeout: f64, backoff: f64, max_retries: u32 },
    /// The flow fails immediately; its DAG dependents never release
    /// (surfaced as `aborted_nodes`).
    Abort,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy::Reroute
    }
}

impl FaultPolicy {
    /// Stable lowercase name for reports (campaign schema v4).
    pub fn name(&self) -> &'static str {
        match self {
            FaultPolicy::Reroute => "reroute",
            FaultPolicy::RetryBackoff { .. } => "retry_backoff",
            FaultPolicy::Abort => "abort",
        }
    }
}

/// A deterministic fault timeline plus the policy for down-link flows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    /// Time-ordered events (non-decreasing `t`; the builders maintain
    /// the order, `WorkloadAnalyzer::analyze_faults` checks it).
    /// Events sharing a timestamp apply in list order.
    pub events: Vec<FaultEvent>,
    pub policy: FaultPolicy,
}

impl FaultSchedule {
    pub fn new(policy: FaultPolicy) -> Self {
        FaultSchedule { events: Vec::new(), policy }
    }

    /// Add one fault, keeping `events` sorted by fire time (an event
    /// inserted at an occupied timestamp lands after the existing
    /// events at that time, so builder order is apply order).
    pub fn at(mut self, t: f64, kind: FaultKind) -> Self {
        let pos = self
            .events
            .partition_point(|e| e.t.total_cmp(&t).is_le());
        self.events.insert(pos, FaultEvent { t, kind });
        self
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Seeded flapping-link generator on the dedicated
    /// [`FAULT_RNG_STREAM`]: `flaps` independent global-link outages
    /// with start times uniform in `[0, horizon_s)` and durations
    /// `mean_outage_s * [0.5, 1.5)`, each paired with its
    /// `LinkRecover`. Only compute-group global links flap (they are
    /// the shared, adaptively-routed resources; NIC faults are modeled
    /// explicitly via `NicDown`/`NodeDown`).
    pub fn random_flaps(
        topo: &Topology,
        flaps: usize,
        horizon_s: f64,
        mean_outage_s: f64,
        seed: u64,
        policy: FaultPolicy,
    ) -> Self {
        let mut rng = Pcg::with_stream(seed, FAULT_RNG_STREAM);
        let groups = topo.cfg.compute_groups as u64;
        let par = topo.cfg.global_links_compute as u64;
        let mut s = FaultSchedule::new(policy);
        for _ in 0..flaps {
            let src = rng.gen_range(groups) as u16;
            let dst =
                ((src as u64 + 1 + rng.gen_range(groups - 1)) % groups) as u16;
            let idx = rng.gen_range(par) as u8;
            let link = LinkId::Global { src, dst, idx };
            let t0 = horizon_s * rng.gen_f64();
            let outage = mean_outage_s * (0.5 + rng.gen_f64());
            s = s
                .at(t0, FaultKind::LinkDown { link })
                .at(t0 + outage, FaultKind::LinkRecover { link });
        }
        s
    }

    /// Every link any event touches (sorted, deduplicated) — the set a
    /// router must invalidate before pricing this schedule.
    pub fn touched_links(&self, nics_per_node: usize) -> Vec<LinkId> {
        let mut out = Vec::new();
        for ev in &self.events {
            ev.kind.link_multipliers(nics_per_node, &mut out);
        }
        let mut links: Vec<LinkId> = out.into_iter().map(|(l, _)| l).collect();
        links.sort_unstable();
        links.dedup();
        links
    }

    /// Nodes down at time `t` (sorted, deduplicated). `NodeDown` is
    /// terminal, so this is every `NodeDown` fired at or before `t`;
    /// pass `f64::INFINITY` for the end-of-run (epilog) state.
    pub fn nodes_down_at(&self, t: f64) -> Vec<u32> {
        let mut down: Vec<u32> = self
            .events
            .iter()
            .filter(|e| e.t.total_cmp(&t).is_le())
            .filter_map(|e| match e.kind {
                FaultKind::NodeDown { node } => Some(node),
                _ => None,
            })
            .collect();
        down.sort_unstable();
        down.dedup();
        down
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    #[test]
    fn builder_keeps_events_time_ordered() {
        let l = LinkId::Global { src: 0, dst: 1, idx: 0 };
        let s = FaultSchedule::new(FaultPolicy::Abort)
            .at(2.0, FaultKind::LinkRecover { link: l })
            .at(0.5, FaultKind::LinkDown { link: l })
            .at(2.0, FaultKind::LinkDegrade { link: l, multiplier: 0.5 })
            .at(1.0, FaultKind::NicDown { endpoint: 3 });
        let ts: Vec<f64> = s.events.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![0.5, 1.0, 2.0, 2.0]);
        // equal timestamps keep builder order: recover before degrade
        assert!(matches!(s.events[2].kind, FaultKind::LinkRecover { .. }));
        assert!(matches!(s.events[3].kind, FaultKind::LinkDegrade { .. }));
    }

    #[test]
    fn random_flaps_is_seed_deterministic_and_paired() {
        let topo = Topology::new(&AuroraConfig::small(6, 4));
        let a = FaultSchedule::random_flaps(
            &topo, 8, 1.0, 0.1, 42, FaultPolicy::Reroute,
        );
        let b = FaultSchedule::random_flaps(
            &topo, 8, 1.0, 0.1, 42, FaultPolicy::Reroute,
        );
        assert_eq!(a, b, "same seed must reproduce the timeline");
        let c = FaultSchedule::random_flaps(
            &topo, 8, 1.0, 0.1, 43, FaultPolicy::Reroute,
        );
        assert_ne!(a, c, "seed must matter");
        assert_eq!(a.len(), 16, "each flap pairs a down with a recover");
        let downs = a
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::LinkDown { .. }))
            .count();
        assert_eq!(downs, 8);
        for ev in &a.events {
            assert!(ev.t.is_finite() && ev.t >= 0.0);
            let link = match ev.kind {
                FaultKind::LinkDown { link }
                | FaultKind::LinkRecover { link } => link,
                _ => panic!("flaps only emit down/recover"),
            };
            assert!(topo.contains_link(&link), "{link:?} outside topology");
        }
        for w in a.events.windows(2) {
            assert!(w[0].t <= w[1].t, "events must be time-ordered");
        }
    }

    #[test]
    fn node_down_expands_to_every_nic_link_and_is_terminal() {
        let topo = Topology::new(&AuroraConfig::small(4, 4));
        let npn = topo.cfg.nics_per_node;
        let s = FaultSchedule::new(FaultPolicy::Abort)
            .at(1.0, FaultKind::NodeDown { node: 2 })
            .at(3.0, FaultKind::NodeDown { node: 5 });
        let links = s.touched_links(npn);
        assert_eq!(links.len(), 2 * npn * 2, "up+down per NIC, two nodes");
        for nic in (2 * npn)..(3 * npn) {
            assert!(links.contains(&LinkId::NicUp(nic as u32)));
            assert!(links.contains(&LinkId::NicDown(nic as u32)));
        }
        assert_eq!(s.nodes_down_at(0.5), Vec::<u32>::new());
        assert_eq!(s.nodes_down_at(1.0), vec![2]);
        assert_eq!(s.nodes_down_at(f64::INFINITY), vec![2, 5]);
    }
}
