//! Flow-level discrete-event simulation with max-min fair sharing and the
//! Slingshot congestion-management behaviour of paper §3.1.
//!
//! Rates are the exact max-min fair allocation (progressive filling with
//! per-flow issue-rate caps); events are flow arrivals and completions.
//! Congestion management models the paper's description literally:
//!
//! > "The switch hardware will detect congestion, identify its causes, and
//! >  determine whether traffic flowing through a congested point is
//! >  contributing to the congestion or is a victim of it. ... stiff back
//! >  pressure to congesting traffic ... All traffic not contributing to
//! >  the congestion is unaffected."
//!
//! With `congestion_mgmt = true`, incast members are rate-limited to their
//! fair share at the *root* of the incast (which exact max-min provides)
//! and victims sharing intermediate links are unaffected. With
//! `congestion_mgmt = false` (the GPCNet "congested" baseline), queues at
//! the incast root back up into the fabric: every flow crossing a link
//! contaminated by incast traffic is penalized.

//! Two solvers share the model above:
//!
//! * [`DesSim::run`] — the **incremental** solver: per-flow rates are held
//!   between events and, at each arrival/completion, only the affected
//!   *component* — flows transitively sharing links with the changed flow —
//!   is re-solved. Components are link-disjoint, so the max-min allocation
//!   of every other component is unchanged by construction; completion
//!   times are projected and kept in an event heap. The component solve is
//!   progressive filling over a per-link flow index with a lazy min-heap of
//!   link fair-share levels (levels are monotone non-decreasing during
//!   filling, so stale heap entries are safely re-inserted).
//! * [`DesSim::run_oracle`] — the original dense full recompute: exact
//!   max-min by whole-system progressive filling at every event. Kept as
//!   the equivalence oracle for `tests/des_equivalence.rs` and the
//!   baseline for `benches/fabric.rs` (see EXPERIMENTS.md §Perf).
//!
//! Both compute the same unique max-min fixpoint, so per-flow finish times
//! agree to floating-point noise (the equivalence suite asserts 1e-9
//! relative).

use super::workload::{DagKind, DagWorkload};
use super::{FlowTimes, RoutedFlow};
use crate::topology::{LinkId, Topology};
use rustc_hash::{FxHashMap, FxHashSet};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// DES knobs.
#[derive(Debug, Clone)]
pub struct DesOpts {
    /// Slingshot congestion management on (paper default) or off.
    pub congestion_mgmt: bool,
    /// Ejection links with at least this many concurrent flows form an
    /// incast.
    pub incast_threshold: usize,
    /// Rate multiplier applied to victims when congestion mgmt is OFF.
    pub victim_penalty: f64,
    /// Degraded links (§3.4 lane-disable): bandwidth multiplier per link.
    pub degraded: HashMap<LinkId, f64>,
    /// Switch per-port queue capacity: bounds how much in-flight bulk data
    /// can sit ahead of a message on each hop (drives the GPCNet latency
    /// inflation of Fig 5).
    pub queue_cap_bytes: f64,
}

impl Default for DesOpts {
    fn default() -> Self {
        Self {
            congestion_mgmt: true,
            incast_threshold: 4,
            victim_penalty: 0.30,
            degraded: HashMap::new(),
            queue_cap_bytes: 256.0 * 1024.0,
        }
    }
}

/// A flow with an arrival time.
#[derive(Debug, Clone)]
pub struct TimedFlow {
    pub rf: RoutedFlow,
    pub start: f64,
}

#[derive(Debug, Clone)]
pub struct DesResult {
    /// Absolute completion time per flow (same order as input).
    pub finish: Vec<f64>,
    pub makespan: f64,
    /// Flows that crossed a congested point as contributors.
    pub contributors: usize,
    /// Flows penalized as victims (only when congestion mgmt is off).
    pub victims: usize,
}

/// Result of executing a [`DagWorkload`] (closed-loop simulation).
#[derive(Debug, Clone)]
pub struct DagResult {
    /// Absolute completion time per DAG node (same order as the
    /// workload's nodes). For transfers this includes the zero-load
    /// latency and entry queueing delay — the time the *receiver* sees
    /// the data and dependents are released.
    pub node_finish: Vec<f64>,
    pub makespan: f64,
    /// Flows that crossed a congested point as contributors.
    pub contributors: usize,
    /// Flows penalized as victims (only when congestion mgmt is off).
    pub victims: usize,
}

pub struct DesSim<'t> {
    topo: &'t Topology,
    opts: DesOpts,
}

/// Interned-link representation of a flow set (see `build_dense`).
struct Dense {
    link_ids: Vec<LinkId>,
    /// Static effective capacity per link (degraded bw + NIC-eff caps).
    cap: Vec<f64>,
    /// Per flow: dense link ids along its path.
    flow_links: Vec<Vec<u32>>,
    /// Per flow: issue-rate cap.
    flow_cap: Vec<f64>,
    /// Per flow: ejection (last) link id.
    flow_last: Vec<u32>,
}

impl<'t> DesSim<'t> {
    pub fn new(topo: &'t Topology, opts: DesOpts) -> Self {
        Self { topo, opts }
    }

    fn link_cap(&self, l: &LinkId) -> f64 {
        let base = self.topo.link_bw(l);
        base * self.opts.degraded.get(l).copied().unwrap_or(1.0)
    }

    /// Build the dense (interned-link) representation used by the solver.
    /// Link ids are interned ONCE per simulation; the per-event max-min
    /// recomputation then runs on flat vectors — this is the §Perf
    /// optimization that took the 512-flow DES from ~38 ms to single-digit
    /// milliseconds (EXPERIMENTS.md §Perf).
    fn build_dense(&self, flows: &[TimedFlow]) -> Dense {
        let mut intern: FxHashMap<LinkId, u32> = FxHashMap::default();
        let mut link_ids: Vec<LinkId> = Vec::new();
        let mut flow_links: Vec<Vec<u32>> = Vec::with_capacity(flows.len());
        let mut flow_cap = Vec::with_capacity(flows.len());
        for tf in flows {
            let mut ls = Vec::with_capacity(tf.rf.path.links.len());
            for l in &tf.rf.path.links {
                let id = *intern.entry(*l).or_insert_with(|| {
                    link_ids.push(*l);
                    (link_ids.len() - 1) as u32
                });
                ls.push(id);
            }
            flow_links.push(ls);
            let c = &self.topo.cfg;
            flow_cap.push(match tf.rf.flow.buf {
                super::BufLoc::Host => c.rank_issue_bw_host,
                super::BufLoc::Gpu => c.rank_issue_bw_gpu,
            });
        }
        // static capacity per link: degraded bandwidth, with NIC endpoint
        // links capped at the effective NIC bandwidth of the buffer types
        // crossing them (PCIe Gen4 practical limit for host, Gen4<->Gen5
        // conversion for GPU buffers — §5.1/Fig 13)
        let mut cap: Vec<f64> =
            link_ids.iter().map(|l| self.link_cap(l)).collect();
        for (fi, tf) in flows.iter().enumerate() {
            let eff = match tf.rf.flow.buf {
                super::BufLoc::Host => self.topo.cfg.nic_eff_bw_host,
                super::BufLoc::Gpu => self.topo.cfg.nic_eff_bw_gpu,
            };
            for (&id, l) in flow_links[fi].iter().zip(&tf.rf.path.links) {
                if matches!(l, LinkId::NicUp(_) | LinkId::NicDown(_)) {
                    cap[id as usize] = cap[id as usize].min(eff);
                }
            }
        }
        let flow_last: Vec<u32> =
            flow_links.iter().map(|ls| *ls.last().unwrap()).collect();
        Dense { link_ids, cap, flow_links, flow_cap, flow_last }
    }

    /// Exact max-min fair rates with per-flow caps (progressive filling)
    /// over the dense representation. `scratch` vectors are reused across
    /// events; `active` holds flow indices. Returns rates aligned with
    /// `active`.
    ///
    /// `rem_cap[l]` is the capacity not yet claimed by fixed flows, so a
    /// link's saturation share is simply `rem_cap / count` — independent
    /// of any global water level. (The original implementation tracked a
    /// global `level` and debited `rate - level`, which let allocations
    /// drift with the fix order and over-commit links shared by flows
    /// fixed after an unrelated cap-fix; see EXPERIMENTS.md §Perf. The
    /// fixpoint here is the unique max-min allocation, which is also what
    /// makes the incremental solver's component-local re-solve exact.)
    #[allow(clippy::too_many_arguments)]
    fn maxmin_dense(
        &self,
        d: &Dense,
        active: &[usize],
        rem_cap: &mut [f64],
        count: &mut [u32],
        touched: &mut Vec<u32>,
    ) -> Vec<f64> {
        let n = active.len();
        let mut rate = vec![f64::NAN; n];
        let mut fixed = vec![false; n];
        touched.clear();
        for &fi in active {
            for &l in &d.flow_links[fi] {
                let li = l as usize;
                if count[li] == 0 {
                    touched.push(l);
                    rem_cap[li] = d.cap[li];
                }
                count[li] += 1;
            }
        }
        let mut n_fixed = 0;
        while n_fixed < n {
            // next binding constraint: a link's fair share or a flow cap
            let mut best_link: Option<(u32, f64)> = None;
            for &l in touched.iter() {
                let li = l as usize;
                if count[li] == 0 {
                    continue;
                }
                let fair = rem_cap[li].max(0.0) / count[li] as f64;
                if best_link.map_or(true, |(_, f)| fair < f) {
                    best_link = Some((l, fair));
                }
            }
            let mut best_flow: Option<(usize, f64)> = None;
            for (idx, &fi) in active.iter().enumerate() {
                if !fixed[idx] {
                    let c = d.flow_cap[fi];
                    if best_flow.map_or(true, |(_, f)| c < f) {
                        best_flow = Some((idx, c));
                    }
                }
            }
            let link_level = best_link.map(|(_, f)| f).unwrap_or(f64::INFINITY);
            let flow_level = best_flow.map(|(_, f)| f).unwrap_or(f64::INFINITY);
            if flow_level <= link_level {
                let (idx, c) = best_flow.unwrap();
                rate[idx] = c;
                fixed[idx] = true;
                n_fixed += 1;
                for &l in &d.flow_links[active[idx]] {
                    rem_cap[l as usize] -= c;
                    count[l as usize] -= 1;
                }
            } else {
                let (l, fair) = best_link.unwrap();
                // fix every unfixed flow crossing l at `fair`
                for (idx, &fi) in active.iter().enumerate() {
                    if !fixed[idx] && d.flow_links[fi].contains(&l) {
                        rate[idx] = fair;
                        fixed[idx] = true;
                        n_fixed += 1;
                        for &ll in &d.flow_links[fi] {
                            rem_cap[ll as usize] -= fair;
                            count[ll as usize] -= 1;
                        }
                    }
                }
                count[l as usize] = 0; // link saturated / dead
            }
        }
        // reset scratch for the next event
        for &l in touched.iter() {
            count[l as usize] = 0;
        }
        rate
    }

    /// Dense-oracle run: full max-min recompute over every active flow at
    /// every event. O(events x flows x links) — correct and simple; the
    /// reference the incremental solver is validated against.
    pub fn run_oracle(&self, flows: &[TimedFlow]) -> DesResult {
        let n = flows.len();
        let d = self.build_dense(flows);
        let n_links = d.link_ids.len();
        let mut remaining: Vec<f64> =
            flows.iter().map(|tf| tf.rf.flow.bytes as f64).collect();
        let mut finish = vec![f64::NAN; n];
        let mut done = vec![false; n];
        let mut now = 0.0_f64;
        let mut n_done = 0;
        let mut contributors_set: FxHashSet<usize> = FxHashSet::default();
        let mut victims_set: FxHashSet<usize> = FxHashSet::default();
        // queueing delay each flow observed when it entered the fabric
        let mut queue_penalty = vec![f64::NAN; n];
        // solver scratch, reused across events
        let mut rem_cap = vec![0.0f64; n_links];
        let mut count = vec![0u32; n_links];
        let mut touched: Vec<u32> = Vec::with_capacity(n_links);
        // per-link scratch for incast detection / queue accounting
        let mut eject_count = vec![0u32; n_links];
        let mut inflight = vec![0.0f64; n_links];
        let mut contaminated = vec![false; n_links];

        while n_done < n {
            let active: Vec<usize> = (0..n)
                .filter(|&i| !done[i] && flows[i].start <= now + 1e-15)
                .collect();
            let next_arrival = flows
                .iter()
                .enumerate()
                .filter(|(i, tf)| !done[*i] && tf.start > now + 1e-15)
                .map(|(_, tf)| tf.start)
                .fold(f64::INFINITY, f64::min);
            if active.is_empty() {
                assert!(next_arrival.is_finite(), "deadlock in DES");
                now = next_arrival;
                continue;
            }

            let mut rates = self.maxmin_dense(
                &d, &active, &mut rem_cap, &mut count, &mut touched,
            );

            // congestion classification: incast ejection links
            for &fi in &active {
                eject_count[d.flow_last[fi] as usize] += 1;
            }
            let is_contrib = |fi: usize| {
                eject_count[d.flow_last[fi] as usize]
                    >= self.opts.incast_threshold as u32
            };
            let any_incast =
                active.iter().any(|&fi| is_contrib(fi));

            // --- queueing delay for newly arrived flows (Fig 5 shape) ---
            // in-flight bytes of OTHER flows sitting on each hop, capped by
            // the switch queue. With congestion management the incast
            // contributors are held at injection (their packets do not
            // pile up in the fabric), so they are excluded.
            if active.iter().any(|&fi| queue_penalty[fi].is_nan()) {
                for &fi in &active {
                    if self.opts.congestion_mgmt && is_contrib(fi) {
                        continue;
                    }
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] += remaining[fi];
                    }
                }
                for &fi in &active {
                    if !queue_penalty[fi].is_nan() {
                        continue;
                    }
                    let mut pen = 0.0;
                    for &l in &d.flow_links[fi] {
                        let queued = (inflight[l as usize] - remaining[fi])
                            .max(0.0)
                            .min(self.opts.queue_cap_bytes);
                        pen += queued / d.cap[l as usize].max(1.0);
                    }
                    queue_penalty[fi] = pen;
                }
                for &fi in &active {
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] = 0.0;
                    }
                }
            }
            if any_incast {
                for &fi in &active {
                    if is_contrib(fi) {
                        contributors_set.insert(fi);
                        for &l in &d.flow_links[fi] {
                            contaminated[l as usize] = true;
                        }
                    }
                }
                if !self.opts.congestion_mgmt {
                    // back-pressure spreads: victims crossing contaminated
                    // links are slowed
                    for (idx, &fi) in active.iter().enumerate() {
                        if is_contrib(fi) {
                            continue; // contributor, already fair-shared
                        }
                        if d.flow_links[fi]
                            .iter()
                            .any(|&l| contaminated[l as usize])
                        {
                            rates[idx] *= self.opts.victim_penalty;
                            victims_set.insert(fi);
                        }
                    }
                }
                for &fi in &active {
                    for &l in &d.flow_links[fi] {
                        contaminated[l as usize] = false;
                    }
                }
            }
            for &fi in &active {
                eject_count[d.flow_last[fi] as usize] = 0;
            }

            // time to next completion
            let mut dt = f64::INFINITY;
            for (idx, &fi) in active.iter().enumerate() {
                if rates[idx] > 0.0 {
                    dt = dt.min(remaining[fi] / rates[idx]);
                }
            }
            dt = dt.min(next_arrival - now);
            assert!(dt.is_finite() && dt >= 0.0, "bad dt {dt}");
            let dt = dt.max(1e-12);
            for (idx, &fi) in active.iter().enumerate() {
                remaining[fi] -= rates[idx] * dt;
            }
            now += dt;
            let cm = super::rounds::CostModel::new(self.topo);
            for &fi in &active {
                if remaining[fi] <= 1e-6 && !done[fi] {
                    done[fi] = true;
                    n_done += 1;
                    // completion includes the zero-load message latency
                    // and the queueing delay seen on entry
                    let tf = &flows[fi];
                    finish[fi] = now
                        + cm.msg_latency(&tf.rf.path, tf.rf.flow.bytes,
                            tf.rf.flow.buf)
                        + if queue_penalty[fi].is_nan() { 0.0 }
                          else { queue_penalty[fi] };
                }
            }
        }
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        DesResult {
            finish,
            makespan,
            contributors: contributors_set.len(),
            victims: victims_set.len(),
        }
    }

    /// Convenience: all flows start at t=0; returns per-flow durations.
    pub fn run_simultaneous(&self, flows: &[RoutedFlow]) -> FlowTimes {
        let timed: Vec<TimedFlow> = flows
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        let res = self.run(&timed);
        FlowTimes::from_vec(res.finish)
    }

    /// Oracle variant of [`run_simultaneous`]: dense full recompute at
    /// every event. Reachable from integration tests and benches.
    pub fn run_simultaneous_oracle(&self, flows: &[RoutedFlow]) -> FlowTimes {
        let timed: Vec<TimedFlow> = flows
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        let res = self.run_oracle(&timed);
        FlowTimes::from_vec(res.finish)
    }

    /// Run the simulation with the **incremental** solver; `flows` keep
    /// their input order in the result.
    ///
    /// Per-flow rates persist between events; at each arrival/completion
    /// only the affected component (flows transitively sharing links with
    /// the changed flows) is re-solved, transferred bytes are synced
    /// lazily per flow, and completions are projected into an event heap.
    /// Components are link-disjoint, so every other flow's max-min rate —
    /// and therefore its projected completion — is unchanged by
    /// construction. Produces the same max-min fixpoint as
    /// [`DesSim::run_oracle`] (unique given caps + capacities), with
    /// finish times equal to floating-point noise.
    pub fn run(&self, flows: &[TimedFlow]) -> DesResult {
        let n = flows.len();
        if n == 0 {
            return DesResult {
                finish: Vec::new(),
                makespan: 0.0,
                contributors: 0,
                victims: 0,
            };
        }
        let d = self.build_dense(flows);
        let n_links = d.link_ids.len();
        let cm = super::rounds::CostModel::new(self.topo);
        let thr = self.opts.incast_threshold as u32;

        // ---- per-flow state ----
        let mut remaining: Vec<f64> =
            flows.iter().map(|tf| tf.rf.flow.bytes as f64).collect();
        let mut rate = vec![0.0f64; n];
        let mut last_sync = vec![0.0f64; n];
        let mut finish = vec![f64::NAN; n];
        let mut queue_penalty = vec![f64::NAN; n];
        let mut active = vec![false; n];
        let mut done = vec![false; n];
        let mut epoch = vec![0u32; n];

        // ---- per-link state: the incremental index both the component
        // walk and the solver run on ----
        let mut link_flows: Vec<Vec<u32>> = vec![Vec::new(); n_links];
        let mut eject_count = vec![0u32; n_links];

        // ---- scratch, reused across events ----
        let mut rem_cap = vec![0.0f64; n_links];
        let mut count = vec![0u32; n_links];
        let mut slot = vec![0u32; n];
        let mut link_seen = vec![0u32; n_links];
        let mut flow_seen = vec![0u32; n];
        let mut stamp = 0u32;
        let mut touched: Vec<u32> = Vec::with_capacity(n_links);
        let mut inflight = vec![0.0f64; n_links];
        let mut contaminated = vec![false; n_links];

        let mut contributors_set: FxHashSet<usize> = FxHashSet::default();
        let mut victims_set: FxHashSet<usize> = FxHashSet::default();

        let mut heap: BinaryHeap<Reverse<Ev>> =
            BinaryHeap::with_capacity(2 * n);
        for (i, tf) in flows.iter().enumerate() {
            heap.push(Reverse(Ev {
                t: tf.start.max(0.0),
                kind: EV_ARRIVAL,
                flow: i as u32,
                epoch: 0,
            }));
        }

        let mut completions: Vec<usize> = Vec::new();
        let mut arrivals: Vec<usize> = Vec::new();
        let mut comp: Vec<usize> = Vec::new();
        let mut lstack: Vec<u32> = Vec::new();
        let mut n_done = 0usize;

        while n_done < n {
            let now = match heap.peek() {
                Some(&Reverse(ev)) => ev.t,
                None => panic!("deadlock in DES: {} flows stalled", n - n_done),
            };
            assert!(now.is_finite(), "deadlock in DES");
            // batch every event at this exact time: completions are applied
            // before arrivals, mirroring the oracle loop structure
            completions.clear();
            arrivals.clear();
            while let Some(&Reverse(ev)) = heap.peek() {
                if ev.t != now {
                    break;
                }
                heap.pop();
                let fi = ev.flow as usize;
                if ev.kind == EV_COMPLETION {
                    // stale completion events are invalidated by epoch bumps
                    if !done[fi] && active[fi] && ev.epoch == epoch[fi] {
                        completions.push(fi);
                    }
                } else if !done[fi] && !active[fi] {
                    arrivals.push(fi);
                }
            }
            if completions.is_empty() && arrivals.is_empty() {
                continue;
            }

            for &fi in &completions {
                done[fi] = true;
                active[fi] = false;
                n_done += 1;
                let tf = &flows[fi];
                finish[fi] = now
                    + cm.msg_latency(&tf.rf.path, tf.rf.flow.bytes,
                        tf.rf.flow.buf)
                    + if queue_penalty[fi].is_nan() { 0.0 }
                      else { queue_penalty[fi] };
                for &l in &d.flow_links[fi] {
                    let lf = &mut link_flows[l as usize];
                    if let Some(pos) = lf.iter().position(|&x| x == fi as u32)
                    {
                        lf.swap_remove(pos);
                    }
                }
                eject_count[d.flow_last[fi] as usize] -= 1;
            }
            for &fi in &arrivals {
                active[fi] = true;
                last_sync[fi] = now;
                for &l in &d.flow_links[fi] {
                    link_flows[l as usize].push(fi as u32);
                }
                eject_count[d.flow_last[fi] as usize] += 1;
            }

            // ---- affected component: walk link <-> flow adjacency from
            // the changed flows' paths ----
            stamp = stamp.wrapping_add(1);
            comp.clear();
            lstack.clear();
            for &fi in completions.iter().chain(arrivals.iter()) {
                for &l in &d.flow_links[fi] {
                    if link_seen[l as usize] != stamp {
                        link_seen[l as usize] = stamp;
                        lstack.push(l);
                    }
                }
            }
            while let Some(l) = lstack.pop() {
                for &fu in &link_flows[l as usize] {
                    let fi = fu as usize;
                    if flow_seen[fi] != stamp {
                        flow_seen[fi] = stamp;
                        comp.push(fi);
                        for &ll in &d.flow_links[fi] {
                            if link_seen[ll as usize] != stamp {
                                link_seen[ll as usize] = stamp;
                                lstack.push(ll);
                            }
                        }
                    }
                }
            }
            if comp.is_empty() {
                continue; // isolated completion: nothing shares its links
            }

            // ---- lazily sync transferred bytes for the component ----
            for &fi in &comp {
                remaining[fi] =
                    (remaining[fi] - rate[fi] * (now - last_sync[fi])).max(0.0);
                last_sync[fi] = now;
            }

            // ---- queueing delay seen by newly arrived flows (identical
            // math to the oracle, restricted to the component — flows in
            // other components share no links with the arrivals) ----
            if comp.iter().any(|&fi| queue_penalty[fi].is_nan()) {
                for &fi in &comp {
                    if self.opts.congestion_mgmt
                        && eject_count[d.flow_last[fi] as usize] >= thr
                    {
                        continue;
                    }
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] += remaining[fi];
                    }
                }
                for &fi in &comp {
                    if !queue_penalty[fi].is_nan() {
                        continue;
                    }
                    let mut pen = 0.0;
                    for &l in &d.flow_links[fi] {
                        let queued = (inflight[l as usize] - remaining[fi])
                            .max(0.0)
                            .min(self.opts.queue_cap_bytes);
                        pen += queued / d.cap[l as usize].max(1.0);
                    }
                    queue_penalty[fi] = pen;
                }
                for &fi in &comp {
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] = 0.0;
                    }
                }
            }

            // ---- exact max-min over the component ----
            let mut rates = self.maxmin_component(
                &d, &comp, &link_flows, &mut rem_cap, &mut count, &mut slot,
                &mut touched,
            );

            // ---- congestion classification (oracle semantics, component
            // scope: contributors and their victims always share links) ----
            let is_contrib =
                |fi: usize| eject_count[d.flow_last[fi] as usize] >= thr;
            let any_incast = comp.iter().any(|&fi| is_contrib(fi));
            if any_incast {
                for &fi in &comp {
                    if is_contrib(fi) {
                        contributors_set.insert(fi);
                        for &l in &d.flow_links[fi] {
                            contaminated[l as usize] = true;
                        }
                    }
                }
                if !self.opts.congestion_mgmt {
                    for (idx, &fi) in comp.iter().enumerate() {
                        if is_contrib(fi) {
                            continue;
                        }
                        if d.flow_links[fi]
                            .iter()
                            .any(|&l| contaminated[l as usize])
                        {
                            rates[idx] *= self.opts.victim_penalty;
                            victims_set.insert(fi);
                        }
                    }
                }
                for &fi in &comp {
                    for &l in &d.flow_links[fi] {
                        contaminated[l as usize] = false;
                    }
                }
            }

            // ---- commit rates and (re)project completions ----
            for (idx, &fi) in comp.iter().enumerate() {
                rate[fi] = rates[idx];
                epoch[fi] = epoch[fi].wrapping_add(1);
                let t_fin = if remaining[fi] <= 1e-6 {
                    now // mirrors the oracle's completion threshold
                } else if rate[fi] > 0.0 {
                    now + remaining[fi] / rate[fi]
                } else {
                    f64::INFINITY
                };
                if t_fin.is_finite() {
                    heap.push(Reverse(Ev {
                        t: t_fin,
                        kind: EV_COMPLETION,
                        flow: fi as u32,
                        epoch: epoch[fi],
                    }));
                }
            }
        }
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        DesResult {
            finish,
            makespan,
            contributors: contributors_set.len(),
            victims: victims_set.len(),
        }
    }

    /// Execute a dependency-released workload (see
    /// [`DagWorkload`]) with the **incremental** solver.
    ///
    /// The event heap gains two dynamic event sources: a flow's bulk
    /// completion schedules its DAG node's completion after the
    /// latency/queue tail, and a node completion releases its dependents
    /// — transfers become arrivals at the release instant (so a round's
    /// completion triggers the next round's arrivals without a full
    /// re-solve), compute intervals schedule their own completion.
    /// Everything else — component walk, lazy byte sync, queueing delay,
    /// max-min, congestion classification — is the arithmetic of
    /// [`DesSim::run`].
    pub fn run_dag(&self, wl: &DagWorkload) -> DagResult {
        self.run_dag_impl(wl, false)
    }

    /// Oracle variant of [`DesSim::run_dag`]: identical dependency
    /// semantics, but every event re-solves the *whole* active flow set
    /// (no component walk, no rate reuse) — the closed-loop analogue of
    /// [`DesSim::run_oracle`], swept against the incremental solver by
    /// `tests/des_equivalence.rs`.
    pub fn run_dag_oracle(&self, wl: &DagWorkload) -> DagResult {
        self.run_dag_impl(wl, true)
    }

    fn run_dag_impl(&self, wl: &DagWorkload, full_resolve: bool) -> DagResult {
        let n_nodes = wl.nodes.len();
        if n_nodes == 0 {
            return DagResult {
                node_finish: Vec::new(),
                makespan: 0.0,
                contributors: 0,
                victims: 0,
            };
        }
        // ---- transfer nodes -> dense flow set ----
        let mut flow_node: Vec<u32> = Vec::new(); // flow idx -> node idx
        let mut node_flow: Vec<u32> = vec![u32::MAX; n_nodes];
        let mut timed: Vec<TimedFlow> = Vec::new();
        for (ni, node) in wl.nodes.iter().enumerate() {
            if let DagKind::Xfer(rf) = &node.kind {
                node_flow[ni] = timed.len() as u32;
                flow_node.push(ni as u32);
                // start is irrelevant here: arrivals are event-driven
                timed.push(TimedFlow { rf: rf.clone(), start: 0.0 });
            }
        }
        let n = timed.len();
        let d = self.build_dense(&timed);
        let n_links = d.link_ids.len();
        let cm = super::rounds::CostModel::new(self.topo);
        let thr = self.opts.incast_threshold as u32;

        // ---- DAG bookkeeping ----
        let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
        let mut deps_left: Vec<u32> = vec![0; n_nodes];
        for (ni, node) in wl.nodes.iter().enumerate() {
            deps_left[ni] = node.deps.len() as u32;
            for &dep in &node.deps {
                succs[dep as usize].push(ni as u32);
            }
        }
        let mut node_finish = vec![f64::NAN; n_nodes];
        let mut node_done = vec![false; n_nodes];
        let mut nodes_done = 0usize;

        // ---- per-flow state (mirrors `run`) ----
        let mut remaining: Vec<f64> =
            timed.iter().map(|tf| tf.rf.flow.bytes as f64).collect();
        let mut rate = vec![0.0f64; n];
        let mut last_sync = vec![0.0f64; n];
        let mut queue_penalty = vec![f64::NAN; n];
        let mut active = vec![false; n];
        let mut done = vec![false; n];
        let mut epoch = vec![0u32; n];
        let mut link_flows: Vec<Vec<u32>> = vec![Vec::new(); n_links];
        let mut eject_count = vec![0u32; n_links];

        // ---- scratch, reused across events ----
        let mut rem_cap = vec![0.0f64; n_links];
        let mut count = vec![0u32; n_links];
        let mut slot = vec![0u32; n];
        let mut link_seen = vec![0u32; n_links];
        let mut flow_seen = vec![0u32; n];
        let mut stamp = 0u32;
        let mut touched: Vec<u32> = Vec::with_capacity(n_links);
        let mut inflight = vec![0.0f64; n_links];
        let mut contaminated = vec![false; n_links];

        let mut contributors_set: FxHashSet<usize> = FxHashSet::default();
        let mut victims_set: FxHashSet<usize> = FxHashSet::default();

        let mut heap: BinaryHeap<Reverse<Ev>> =
            BinaryHeap::with_capacity(2 * n_nodes);
        for (ni, node) in wl.nodes.iter().enumerate() {
            if node.deps.is_empty() {
                let t0 = node.start.max(0.0);
                match &node.kind {
                    DagKind::Xfer(_) => heap.push(Reverse(Ev {
                        t: t0,
                        kind: EV_ARRIVAL,
                        flow: node_flow[ni],
                        epoch: 0,
                    })),
                    DagKind::Compute(dt) => heap.push(Reverse(Ev {
                        t: t0 + dt.max(0.0),
                        kind: EV_NODE,
                        flow: ni as u32,
                        epoch: 0,
                    })),
                }
            }
        }

        let mut completions: Vec<usize> = Vec::new();
        let mut arrivals: Vec<usize> = Vec::new();
        let mut finished_nodes: Vec<u32> = Vec::new();
        let mut comp: Vec<usize> = Vec::new();
        let mut lstack: Vec<u32> = Vec::new();

        while nodes_done < n_nodes {
            let now = match heap.peek() {
                Some(&Reverse(ev)) => ev.t,
                None => panic!(
                    "deadlock in closed-loop DES: {} of {n_nodes} nodes \
                     never released",
                    n_nodes - nodes_done
                ),
            };
            assert!(now.is_finite(), "deadlock in closed-loop DES");
            completions.clear();
            arrivals.clear();
            finished_nodes.clear();
            while let Some(&Reverse(ev)) = heap.peek() {
                if ev.t != now {
                    break;
                }
                heap.pop();
                let fi = ev.flow as usize;
                match ev.kind {
                    EV_COMPLETION => {
                        if !done[fi] && active[fi] && ev.epoch == epoch[fi] {
                            completions.push(fi);
                        }
                    }
                    EV_ARRIVAL => {
                        if !done[fi] && !active[fi] {
                            arrivals.push(fi);
                        }
                    }
                    // EV_NODE: `flow` carries the DAG node id
                    _ => finished_nodes.push(ev.flow),
                }
            }

            // ---- flow completions: the bulk leaves the fabric now; the
            // DAG node completes after the latency/queue tail ----
            for &fi in &completions {
                done[fi] = true;
                active[fi] = false;
                let tf = &timed[fi];
                let tail = cm.msg_latency(
                    &tf.rf.path,
                    tf.rf.flow.bytes,
                    tf.rf.flow.buf,
                ) + if queue_penalty[fi].is_nan() {
                    0.0
                } else {
                    queue_penalty[fi]
                };
                for &l in &d.flow_links[fi] {
                    let lf = &mut link_flows[l as usize];
                    if let Some(pos) =
                        lf.iter().position(|&x| x == fi as u32)
                    {
                        lf.swap_remove(pos);
                    }
                }
                eject_count[d.flow_last[fi] as usize] -= 1;
                heap.push(Reverse(Ev {
                    t: now + tail,
                    kind: EV_NODE,
                    flow: flow_node[fi],
                    epoch: 0,
                }));
            }

            // ---- node completions: release dependents. Zero-length
            // compute chains collapse within the same instant (the list
            // grows while we walk it). ----
            let mut k = 0;
            while k < finished_nodes.len() {
                let ni = finished_nodes[k] as usize;
                k += 1;
                debug_assert!(!node_done[ni], "node {ni} finished twice");
                node_done[ni] = true;
                node_finish[ni] = now;
                nodes_done += 1;
                for &su in &succs[ni] {
                    let s = su as usize;
                    deps_left[s] -= 1;
                    if deps_left[s] > 0 {
                        continue;
                    }
                    let rel = wl.nodes[s].start.max(now);
                    match &wl.nodes[s].kind {
                        DagKind::Xfer(_) => {
                            let fi = node_flow[s];
                            if rel <= now {
                                arrivals.push(fi as usize);
                            } else {
                                heap.push(Reverse(Ev {
                                    t: rel,
                                    kind: EV_ARRIVAL,
                                    flow: fi,
                                    epoch: 0,
                                }));
                            }
                        }
                        DagKind::Compute(dt) => {
                            let t_fin = rel + dt.max(0.0);
                            if t_fin <= now {
                                finished_nodes.push(s as u32);
                            } else {
                                heap.push(Reverse(Ev {
                                    t: t_fin,
                                    kind: EV_NODE,
                                    flow: s as u32,
                                    epoch: 0,
                                }));
                            }
                        }
                    }
                }
            }

            for &fi in &arrivals {
                active[fi] = true;
                last_sync[fi] = now;
                for &l in &d.flow_links[fi] {
                    link_flows[l as usize].push(fi as u32);
                }
                eject_count[d.flow_last[fi] as usize] += 1;
            }
            if completions.is_empty() && arrivals.is_empty() {
                continue; // pure node bookkeeping: no rate change
            }

            // ---- affected component (or, for the oracle, everything) ----
            comp.clear();
            if full_resolve {
                comp.extend((0..n).filter(|&fi| active[fi]));
            } else {
                stamp = stamp.wrapping_add(1);
                lstack.clear();
                for &fi in completions.iter().chain(arrivals.iter()) {
                    for &l in &d.flow_links[fi] {
                        if link_seen[l as usize] != stamp {
                            link_seen[l as usize] = stamp;
                            lstack.push(l);
                        }
                    }
                }
                while let Some(l) = lstack.pop() {
                    for &fu in &link_flows[l as usize] {
                        let fi = fu as usize;
                        if flow_seen[fi] != stamp {
                            flow_seen[fi] = stamp;
                            comp.push(fi);
                            for &ll in &d.flow_links[fi] {
                                if link_seen[ll as usize] != stamp {
                                    link_seen[ll as usize] = stamp;
                                    lstack.push(ll);
                                }
                            }
                        }
                    }
                }
            }
            if comp.is_empty() {
                continue; // isolated completion: nothing shares its links
            }

            // ---- lazily sync transferred bytes ----
            for &fi in &comp {
                remaining[fi] = (remaining[fi]
                    - rate[fi] * (now - last_sync[fi]))
                    .max(0.0);
                last_sync[fi] = now;
            }

            // ---- queueing delay for newly arrived flows (identical
            // arithmetic to `run`) ----
            if comp.iter().any(|&fi| queue_penalty[fi].is_nan()) {
                for &fi in &comp {
                    if self.opts.congestion_mgmt
                        && eject_count[d.flow_last[fi] as usize] >= thr
                    {
                        continue;
                    }
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] += remaining[fi];
                    }
                }
                for &fi in &comp {
                    if !queue_penalty[fi].is_nan() {
                        continue;
                    }
                    let mut pen = 0.0;
                    for &l in &d.flow_links[fi] {
                        let queued = (inflight[l as usize] - remaining[fi])
                            .max(0.0)
                            .min(self.opts.queue_cap_bytes);
                        pen += queued / d.cap[l as usize].max(1.0);
                    }
                    queue_penalty[fi] = pen;
                }
                for &fi in &comp {
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] = 0.0;
                    }
                }
            }

            // ---- exact max-min over the component ----
            let mut rates = self.maxmin_component(
                &d, &comp, &link_flows, &mut rem_cap, &mut count, &mut slot,
                &mut touched,
            );

            // ---- congestion classification (identical to `run`) ----
            let is_contrib =
                |fi: usize| eject_count[d.flow_last[fi] as usize] >= thr;
            let any_incast = comp.iter().any(|&fi| is_contrib(fi));
            if any_incast {
                for &fi in &comp {
                    if is_contrib(fi) {
                        contributors_set.insert(fi);
                        for &l in &d.flow_links[fi] {
                            contaminated[l as usize] = true;
                        }
                    }
                }
                if !self.opts.congestion_mgmt {
                    for (idx, &fi) in comp.iter().enumerate() {
                        if is_contrib(fi) {
                            continue;
                        }
                        if d.flow_links[fi]
                            .iter()
                            .any(|&l| contaminated[l as usize])
                        {
                            rates[idx] *= self.opts.victim_penalty;
                            victims_set.insert(fi);
                        }
                    }
                }
                for &fi in &comp {
                    for &l in &d.flow_links[fi] {
                        contaminated[l as usize] = false;
                    }
                }
            }

            // ---- commit rates and (re)project completions ----
            for (idx, &fi) in comp.iter().enumerate() {
                rate[fi] = rates[idx];
                epoch[fi] = epoch[fi].wrapping_add(1);
                let t_fin = if remaining[fi] <= 1e-6 {
                    now
                } else if rate[fi] > 0.0 {
                    now + remaining[fi] / rate[fi]
                } else {
                    f64::INFINITY
                };
                if t_fin.is_finite() {
                    heap.push(Reverse(Ev {
                        t: t_fin,
                        kind: EV_COMPLETION,
                        flow: fi as u32,
                        epoch: epoch[fi],
                    }));
                }
            }
        }
        let makespan = node_finish.iter().cloned().fold(0.0, f64::max);
        DagResult {
            node_finish,
            makespan,
            contributors: contributors_set.len(),
            victims: victims_set.len(),
        }
    }

    /// Exact max-min (progressive filling with per-flow caps) restricted
    /// to one component, driven by the per-link active-flow index instead
    /// of whole-system scans. Same math as [`DesSim::maxmin_dense`]
    /// (`fair = rem_cap / count`), so the two solvers reach the same
    /// unique fixpoint.
    ///
    /// Fair shares are monotone non-decreasing during filling (a flow is
    /// only ever fixed at `c <=` every remaining link's fair share, and
    /// removing it raises that share: `(rem - c)/(count - 1) >=
    /// rem/count` when `c <= rem/count`), so the link heap may hold
    /// stale, smaller keys; entries are re-validated and re-inserted on
    /// pop. `slot`, `rem_cap`, `count` and `touched` are caller-owned
    /// scratch, zeroed on return.
    #[allow(clippy::too_many_arguments)]
    fn maxmin_component(
        &self,
        d: &Dense,
        comp: &[usize],
        link_flows: &[Vec<u32>],
        rem_cap: &mut [f64],
        count: &mut [u32],
        slot: &mut [u32],
        touched: &mut Vec<u32>,
    ) -> Vec<f64> {
        let nc = comp.len();
        let mut rates = vec![f64::NAN; nc];
        let mut fixed = vec![false; nc];
        touched.clear();
        for (idx, &fi) in comp.iter().enumerate() {
            slot[fi] = idx as u32 + 1;
            for &l in &d.flow_links[fi] {
                let li = l as usize;
                if count[li] == 0 {
                    touched.push(l);
                    rem_cap[li] = d.cap[li];
                }
                count[li] += 1;
            }
        }
        // flows sorted by issue cap: the "next flow-cap constraint" pointer
        let mut cap_order: Vec<u32> = (0..nc as u32).collect();
        cap_order.sort_unstable_by(|&a, &b| {
            d.flow_cap[comp[a as usize]]
                .total_cmp(&d.flow_cap[comp[b as usize]])
        });
        let mut cap_ptr = 0usize;
        let mut lheap: BinaryHeap<Reverse<LinkLevel>> = touched
            .iter()
            .map(|&l| {
                let li = l as usize;
                Reverse(LinkLevel {
                    fair: rem_cap[li].max(0.0) / count[li] as f64,
                    link: l,
                })
            })
            .collect();
        let mut n_fixed = 0usize;
        while n_fixed < nc {
            // next binding link constraint (lazy re-validation)
            let link_cand = loop {
                match lheap.peek() {
                    None => break None,
                    Some(&Reverse(LinkLevel { fair, link })) => {
                        let li = link as usize;
                        if count[li] == 0 {
                            lheap.pop();
                            continue;
                        }
                        let cur = rem_cap[li].max(0.0) / count[li] as f64;
                        if cur > fair {
                            lheap.pop();
                            lheap.push(Reverse(LinkLevel { fair: cur, link }));
                            continue;
                        }
                        break Some((link, cur));
                    }
                }
            };
            while cap_ptr < nc && fixed[cap_order[cap_ptr] as usize] {
                cap_ptr += 1;
            }
            let flow_cand = if cap_ptr < nc {
                let s = cap_order[cap_ptr] as usize;
                Some((s, d.flow_cap[comp[s]]))
            } else {
                None
            };
            let link_level = link_cand.map_or(f64::INFINITY, |(_, f)| f);
            let flow_level = flow_cand.map_or(f64::INFINITY, |(_, f)| f);
            if flow_level <= link_level {
                let (s, c) =
                    flow_cand.expect("unfixed flow implies a cap constraint");
                rates[s] = c;
                fixed[s] = true;
                n_fixed += 1;
                for &l in &d.flow_links[comp[s]] {
                    rem_cap[l as usize] -= c;
                    count[l as usize] -= 1;
                }
            } else {
                let (l, fair) = link_cand.expect("link level was finite");
                for &fu in &link_flows[l as usize] {
                    debug_assert!(
                        slot[fu as usize] > 0,
                        "link member outside component"
                    );
                    let s = (slot[fu as usize] - 1) as usize;
                    if fixed[s] {
                        continue;
                    }
                    rates[s] = fair;
                    fixed[s] = true;
                    n_fixed += 1;
                    for &ll in &d.flow_links[fu as usize] {
                        rem_cap[ll as usize] -= fair;
                        count[ll as usize] -= 1;
                    }
                }
                count[l as usize] = 0; // saturated / dead
            }
        }
        for &l in touched.iter() {
            count[l as usize] = 0;
        }
        for &fi in comp {
            slot[fi] = 0;
        }
        rates
    }
}

const EV_COMPLETION: u8 = 0;
const EV_ARRIVAL: u8 = 1;
/// DAG-node completion (closed-loop runs only): `Ev::flow` carries the
/// workload node id, not a flow index.
const EV_NODE: u8 = 2;

/// Heap event for the incremental solver (min-heap through `Reverse`):
/// ordered by time, completions before arrivals at equal times.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Ev {
    t: f64,
    kind: u8,
    flow: u32,
    epoch: u32,
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then_with(|| self.kind.cmp(&other.kind))
            .then_with(|| self.flow.cmp(&other.flow))
            .then_with(|| self.epoch.cmp(&other.epoch))
    }
}

/// Lazy-heap entry for `maxmin_component`: a link's prospective fair-share
/// water level at the time it was (re)inserted.
#[derive(Debug, Clone, Copy, PartialEq)]
struct LinkLevel {
    fair: f64,
    link: u32,
}

impl Eq for LinkLevel {}

impl PartialOrd for LinkLevel {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for LinkLevel {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.fair
            .total_cmp(&other.fair)
            .then_with(|| self.link.cmp(&other.link))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::fabric::{Flow, Router};

    fn setup() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    fn routed(topo: &Topology, flows: Vec<Flow>) -> Vec<RoutedFlow> {
        let mut r = Router::new(topo);
        flows
            .into_iter()
            .map(|f| RoutedFlow { path: r.route(&f), flow: f })
            .collect()
    }

    #[test]
    fn single_flow_rate_matches_issue_cap() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 1u64 << 30;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let res = sim.run_simultaneous(&fl);
        let rate = bytes as f64 / res.makespan;
        let cap = t.cfg.rank_issue_bw_host;
        assert!((rate - cap).abs() / cap < 0.02, "rate {rate} cap {cap}");
    }

    #[test]
    fn nic_sharing_halves_rates() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 1u64 << 30;
        // two ranks on the same NIC: fair share of nic_bw
        let fl = routed(
            &t,
            vec![Flow::new(0, 200, bytes), Flow::new(0, 208, bytes)],
        );
        let res = sim.run_simultaneous(&fl);
        let agg = 2.0 * bytes as f64 / res.makespan;
        assert!(agg <= t.cfg.nic_bw * 1.02, "aggregate {agg}");
        // but two ranks *do* push the NIC harder than one rank could
        assert!(agg > t.cfg.rank_issue_bw_host * 1.3);
    }

    #[test]
    fn incast_contributors_share_ejection_fairly() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 64u64 << 20;
        // 8-to-1 incast onto NIC 200
        let fl = routed(
            &t,
            (0..8).map(|i| Flow::new(i * 8, 200, bytes)).collect(),
        );
        let res = sim.run_simultaneous(&fl);
        let agg = 8.0 * bytes as f64 / res.makespan;
        assert!(agg <= t.cfg.nic_bw * 1.05, "incast exceeds ejection: {agg}");
    }

    #[test]
    fn victims_protected_with_congestion_mgmt() {
        let t = setup();
        let bytes = 16u64 << 20;
        // incast from group 1 NICs onto NIC 200 + one victim 0 -> 300
        let mut flows: Vec<Flow> =
            (0..6).map(|i| Flow::new(128 + i * 8, 200, bytes)).collect();
        flows.push(Flow::new(0, 300, bytes));
        let fl = routed(&t, flows);
        let on = DesSim::new(&t, DesOpts { congestion_mgmt: true, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let off = DesSim::new(&t, DesOpts { congestion_mgmt: false, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let victim_on = on.per_flow[6];
        let victim_off = off.per_flow[6];
        // victim may or may not share links; congestion mgmt must never be
        // worse, and when contaminated it is strictly better
        assert!(victim_on <= victim_off * 1.01,
            "victim with mgmt {victim_on} vs without {victim_off}");
    }

    #[test]
    fn congestion_off_hurts_crossing_victims() {
        let t = setup();
        let bytes = 16u64 << 20;
        // incast flows ejecting at NIC 200 (group 0... NIC200 is in group 3
        // region), victim shares the source group links
        let mut flows: Vec<Flow> =
            (0..8).map(|i| Flow::new(i * 8, 200, bytes)).collect();
        // victim from same source switch as contributor 0, different dest
        flows.push(Flow::new(1, 210, bytes));
        let fl = routed(&t, flows);
        let off = DesSim::new(&t, DesOpts { congestion_mgmt: false, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let on = DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        assert!(off.per_flow[8] >= on.per_flow[8],
            "victim must not be faster without congestion mgmt");
    }

    #[test]
    fn degraded_link_slows_flows() {
        let t = setup();
        let bytes = 64u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let healthy = DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        let mut degraded = HashMap::new();
        // half the lanes on every link of this path (§3.4 degraded mode)
        for l in &fl[0].path.links {
            degraded.insert(*l, 0.5);
        }
        let slow = DesSim::new(&t, DesOpts { degraded, ..DesOpts::default() })
            .run_simultaneous(&fl);
        assert!(slow.makespan > healthy.makespan * 1.05);
    }

    #[test]
    fn staggered_arrivals_respected() {
        let t = setup();
        let bytes = 16u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let sim = DesSim::new(&t, DesOpts::default());
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 1.0 }];
        let res = sim.run(&timed);
        assert!(res.finish[0] > 1.0);
    }

    fn assert_equivalent(opts: DesOpts, topo: &Topology, timed: &[TimedFlow]) {
        let sim = DesSim::new(topo, opts);
        let inc = sim.run(timed);
        let ora = sim.run_oracle(timed);
        for (i, (a, b)) in inc.finish.iter().zip(&ora.finish).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1e-30);
            assert!(rel < 1e-9, "flow {i}: inc {a} vs oracle {b}");
        }
        assert_eq!(inc.contributors, ora.contributors, "contributor sets");
        assert_eq!(inc.victims, ora.victims, "victim sets");
    }

    #[test]
    fn incremental_matches_oracle_incast() {
        let t = setup();
        let fl = routed(
            &t,
            (0..8).map(|i| Flow::new(i * 8, 200, 32u64 << 20)).collect(),
        );
        let timed: Vec<TimedFlow> = fl
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        assert_equivalent(DesOpts::default(), &t, &timed);
        assert_equivalent(
            DesOpts { congestion_mgmt: false, ..DesOpts::default() },
            &t,
            &timed,
        );
    }

    #[test]
    fn incremental_matches_oracle_staggered() {
        let t = setup();
        let fl = routed(
            &t,
            (0..12)
                .map(|i| Flow::new(i * 4, 128 + i * 2, (4u64 + i as u64) << 20))
                .collect(),
        );
        let timed: Vec<TimedFlow> = fl
            .iter()
            .enumerate()
            .map(|(i, rf)| TimedFlow {
                rf: rf.clone(),
                start: (i % 4) as f64 * 1e-3,
            })
            .collect();
        assert_equivalent(DesOpts::default(), &t, &timed);
    }

    #[test]
    fn incremental_matches_oracle_disjoint_components() {
        // two flow groups in different dragonfly groups: the incremental
        // solver must keep them in independent components
        let t = setup();
        // group 0 -> group 3 and group 1 -> group 2 (64 NICs per group in
        // small(4,4)): disjoint NICs, locals and globals
        let mut flows: Vec<Flow> =
            (0..4).map(|i| Flow::new(i, 200 + i, 8u64 << 20)).collect();
        flows.extend((0..4).map(|i| Flow::new(64 + i, 128 + i, 8u64 << 20)));
        let fl = routed(&t, flows);
        let timed: Vec<TimedFlow> = fl
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        assert_equivalent(DesOpts::default(), &t, &timed);
    }
}
