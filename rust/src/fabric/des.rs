//! Flow-level discrete-event simulation with max-min fair sharing and the
//! Slingshot congestion-management behaviour of paper §3.1.
//!
//! Rates are the exact max-min fair allocation (progressive filling with
//! per-flow issue-rate caps); events are flow arrivals and completions.
//! Congestion management models the paper's description literally:
//!
//! > "The switch hardware will detect congestion, identify its causes, and
//! >  determine whether traffic flowing through a congested point is
//! >  contributing to the congestion or is a victim of it. ... stiff back
//! >  pressure to congesting traffic ... All traffic not contributing to
//! >  the congestion is unaffected."
//!
//! With `congestion_mgmt = true`, incast members are rate-limited to their
//! fair share at the *root* of the incast (which exact max-min provides)
//! and victims sharing intermediate links are unaffected. With
//! `congestion_mgmt = false` (the GPCNet "congested" baseline), queues at
//! the incast root back up into the fabric: every flow crossing a link
//! contaminated by incast traffic is penalized.

use super::{FlowTimes, RoutedFlow};
use crate::topology::{LinkId, Topology};
use rustc_hash::{FxHashMap, FxHashSet};
use std::collections::HashMap;

/// DES knobs.
#[derive(Debug, Clone)]
pub struct DesOpts {
    /// Slingshot congestion management on (paper default) or off.
    pub congestion_mgmt: bool,
    /// Ejection links with at least this many concurrent flows form an
    /// incast.
    pub incast_threshold: usize,
    /// Rate multiplier applied to victims when congestion mgmt is OFF.
    pub victim_penalty: f64,
    /// Degraded links (§3.4 lane-disable): bandwidth multiplier per link.
    pub degraded: HashMap<LinkId, f64>,
    /// Switch per-port queue capacity: bounds how much in-flight bulk data
    /// can sit ahead of a message on each hop (drives the GPCNet latency
    /// inflation of Fig 5).
    pub queue_cap_bytes: f64,
}

impl Default for DesOpts {
    fn default() -> Self {
        Self {
            congestion_mgmt: true,
            incast_threshold: 4,
            victim_penalty: 0.30,
            degraded: HashMap::new(),
            queue_cap_bytes: 256.0 * 1024.0,
        }
    }
}

/// A flow with an arrival time.
#[derive(Debug, Clone)]
pub struct TimedFlow {
    pub rf: RoutedFlow,
    pub start: f64,
}

#[derive(Debug, Clone)]
pub struct DesResult {
    /// Absolute completion time per flow (same order as input).
    pub finish: Vec<f64>,
    pub makespan: f64,
    /// Flows that crossed a congested point as contributors.
    pub contributors: usize,
    /// Flows penalized as victims (only when congestion mgmt is off).
    pub victims: usize,
}

pub struct DesSim<'t> {
    topo: &'t Topology,
    opts: DesOpts,
}

/// Interned-link representation of a flow set (see `build_dense`).
struct Dense {
    link_ids: Vec<LinkId>,
    /// Static effective capacity per link (degraded bw + NIC-eff caps).
    cap: Vec<f64>,
    /// Per flow: dense link ids along its path.
    flow_links: Vec<Vec<u32>>,
    /// Per flow: issue-rate cap.
    flow_cap: Vec<f64>,
    /// Per flow: ejection (last) link id.
    flow_last: Vec<u32>,
}

impl<'t> DesSim<'t> {
    pub fn new(topo: &'t Topology, opts: DesOpts) -> Self {
        Self { topo, opts }
    }

    fn link_cap(&self, l: &LinkId) -> f64 {
        let base = self.topo.link_bw(l);
        base * self.opts.degraded.get(l).copied().unwrap_or(1.0)
    }

    /// Build the dense (interned-link) representation used by the solver.
    /// Link ids are interned ONCE per simulation; the per-event max-min
    /// recomputation then runs on flat vectors — this is the §Perf
    /// optimization that took the 512-flow DES from ~38 ms to single-digit
    /// milliseconds (EXPERIMENTS.md §Perf).
    fn build_dense(&self, flows: &[TimedFlow]) -> Dense {
        let mut intern: FxHashMap<LinkId, u32> = FxHashMap::default();
        let mut link_ids: Vec<LinkId> = Vec::new();
        let mut flow_links: Vec<Vec<u32>> = Vec::with_capacity(flows.len());
        let mut flow_cap = Vec::with_capacity(flows.len());
        for tf in flows {
            let mut ls = Vec::with_capacity(tf.rf.path.links.len());
            for l in &tf.rf.path.links {
                let id = *intern.entry(*l).or_insert_with(|| {
                    link_ids.push(*l);
                    (link_ids.len() - 1) as u32
                });
                ls.push(id);
            }
            flow_links.push(ls);
            let c = &self.topo.cfg;
            flow_cap.push(match tf.rf.flow.buf {
                super::BufLoc::Host => c.rank_issue_bw_host,
                super::BufLoc::Gpu => c.rank_issue_bw_gpu,
            });
        }
        // static capacity per link: degraded bandwidth, with NIC endpoint
        // links capped at the effective NIC bandwidth of the buffer types
        // crossing them (PCIe Gen4 practical limit for host, Gen4<->Gen5
        // conversion for GPU buffers — §5.1/Fig 13)
        let mut cap: Vec<f64> =
            link_ids.iter().map(|l| self.link_cap(l)).collect();
        for (fi, tf) in flows.iter().enumerate() {
            let eff = match tf.rf.flow.buf {
                super::BufLoc::Host => self.topo.cfg.nic_eff_bw_host,
                super::BufLoc::Gpu => self.topo.cfg.nic_eff_bw_gpu,
            };
            for (&id, l) in flow_links[fi].iter().zip(&tf.rf.path.links) {
                if matches!(l, LinkId::NicUp(_) | LinkId::NicDown(_)) {
                    cap[id as usize] = cap[id as usize].min(eff);
                }
            }
        }
        let flow_last: Vec<u32> =
            flow_links.iter().map(|ls| *ls.last().unwrap()).collect();
        Dense { link_ids, cap, flow_links, flow_cap, flow_last }
    }

    /// Exact max-min fair rates with per-flow caps (progressive filling)
    /// over the dense representation. `scratch` vectors are reused across
    /// events; `active` holds flow indices. Returns rates aligned with
    /// `active`.
    #[allow(clippy::too_many_arguments)]
    fn maxmin_dense(
        &self,
        d: &Dense,
        active: &[usize],
        rem_cap: &mut [f64],
        count: &mut [u32],
        touched: &mut Vec<u32>,
    ) -> Vec<f64> {
        let n = active.len();
        let mut rate = vec![f64::NAN; n];
        let mut fixed = vec![false; n];
        touched.clear();
        for &fi in active {
            for &l in &d.flow_links[fi] {
                let li = l as usize;
                if count[li] == 0 {
                    touched.push(l);
                    rem_cap[li] = d.cap[li];
                }
                count[li] += 1;
            }
        }
        let mut n_fixed = 0;
        let mut level = 0.0_f64;
        while n_fixed < n {
            // next binding constraint: a link's fair share or a flow cap
            let mut best_link: Option<(u32, f64)> = None;
            for &l in touched.iter() {
                let li = l as usize;
                if count[li] == 0 {
                    continue;
                }
                let fair = level + rem_cap[li].max(0.0) / count[li] as f64;
                if best_link.map_or(true, |(_, f)| fair < f) {
                    best_link = Some((l, fair));
                }
            }
            let mut best_flow: Option<(usize, f64)> = None;
            for (idx, &fi) in active.iter().enumerate() {
                if !fixed[idx] {
                    let c = d.flow_cap[fi];
                    if best_flow.map_or(true, |(_, f)| c < f) {
                        best_flow = Some((idx, c));
                    }
                }
            }
            let link_level = best_link.map(|(_, f)| f).unwrap_or(f64::INFINITY);
            let flow_level = best_flow.map(|(_, f)| f).unwrap_or(f64::INFINITY);
            if flow_level <= link_level {
                let (idx, c) = best_flow.unwrap();
                rate[idx] = c;
                fixed[idx] = true;
                n_fixed += 1;
                for &l in &d.flow_links[active[idx]] {
                    rem_cap[l as usize] -= c - level;
                    count[l as usize] -= 1;
                }
                level = c;
            } else {
                let (l, fair) = best_link.unwrap();
                // fix every unfixed flow crossing l at `fair`
                let mut fixed_any = false;
                for (idx, &fi) in active.iter().enumerate() {
                    if !fixed[idx] && d.flow_links[fi].contains(&l) {
                        rate[idx] = fair;
                        fixed[idx] = true;
                        fixed_any = true;
                        n_fixed += 1;
                        for &ll in &d.flow_links[fi] {
                            rem_cap[ll as usize] -= fair - level;
                            count[ll as usize] -= 1;
                        }
                    }
                }
                count[l as usize] = 0; // link saturated / dead
                if fixed_any {
                    level = fair;
                }
            }
        }
        // reset scratch for the next event
        for &l in touched.iter() {
            count[l as usize] = 0;
        }
        rate
    }

    /// Run the simulation; `flows` keep their input order in the result.
    pub fn run(&self, flows: &[TimedFlow]) -> DesResult {
        let n = flows.len();
        let d = self.build_dense(flows);
        let n_links = d.link_ids.len();
        let mut remaining: Vec<f64> =
            flows.iter().map(|tf| tf.rf.flow.bytes as f64).collect();
        let mut finish = vec![f64::NAN; n];
        let mut done = vec![false; n];
        let mut now = 0.0_f64;
        let mut n_done = 0;
        let mut contributors_set: FxHashSet<usize> = FxHashSet::default();
        let mut victims_set: FxHashSet<usize> = FxHashSet::default();
        // queueing delay each flow observed when it entered the fabric
        let mut queue_penalty = vec![f64::NAN; n];
        // solver scratch, reused across events
        let mut rem_cap = vec![0.0f64; n_links];
        let mut count = vec![0u32; n_links];
        let mut touched: Vec<u32> = Vec::with_capacity(n_links);
        // per-link scratch for incast detection / queue accounting
        let mut eject_count = vec![0u32; n_links];
        let mut inflight = vec![0.0f64; n_links];
        let mut contaminated = vec![false; n_links];

        while n_done < n {
            let active: Vec<usize> = (0..n)
                .filter(|&i| !done[i] && flows[i].start <= now + 1e-15)
                .collect();
            let next_arrival = flows
                .iter()
                .enumerate()
                .filter(|(i, tf)| !done[*i] && tf.start > now + 1e-15)
                .map(|(_, tf)| tf.start)
                .fold(f64::INFINITY, f64::min);
            if active.is_empty() {
                assert!(next_arrival.is_finite(), "deadlock in DES");
                now = next_arrival;
                continue;
            }

            let mut rates = self.maxmin_dense(
                &d, &active, &mut rem_cap, &mut count, &mut touched,
            );

            // congestion classification: incast ejection links
            for &fi in &active {
                eject_count[d.flow_last[fi] as usize] += 1;
            }
            let is_contrib = |fi: usize| {
                eject_count[d.flow_last[fi] as usize]
                    >= self.opts.incast_threshold as u32
            };
            let any_incast =
                active.iter().any(|&fi| is_contrib(fi));

            // --- queueing delay for newly arrived flows (Fig 5 shape) ---
            // in-flight bytes of OTHER flows sitting on each hop, capped by
            // the switch queue. With congestion management the incast
            // contributors are held at injection (their packets do not
            // pile up in the fabric), so they are excluded.
            if active.iter().any(|&fi| queue_penalty[fi].is_nan()) {
                for &fi in &active {
                    if self.opts.congestion_mgmt && is_contrib(fi) {
                        continue;
                    }
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] += remaining[fi];
                    }
                }
                for &fi in &active {
                    if !queue_penalty[fi].is_nan() {
                        continue;
                    }
                    let mut pen = 0.0;
                    for &l in &d.flow_links[fi] {
                        let queued = (inflight[l as usize] - remaining[fi])
                            .max(0.0)
                            .min(self.opts.queue_cap_bytes);
                        pen += queued / d.cap[l as usize].max(1.0);
                    }
                    queue_penalty[fi] = pen;
                }
                for &fi in &active {
                    for &l in &d.flow_links[fi] {
                        inflight[l as usize] = 0.0;
                    }
                }
            }
            if any_incast {
                for &fi in &active {
                    if is_contrib(fi) {
                        contributors_set.insert(fi);
                        for &l in &d.flow_links[fi] {
                            contaminated[l as usize] = true;
                        }
                    }
                }
                if !self.opts.congestion_mgmt {
                    // back-pressure spreads: victims crossing contaminated
                    // links are slowed
                    for (idx, &fi) in active.iter().enumerate() {
                        if is_contrib(fi) {
                            continue; // contributor, already fair-shared
                        }
                        if d.flow_links[fi]
                            .iter()
                            .any(|&l| contaminated[l as usize])
                        {
                            rates[idx] *= self.opts.victim_penalty;
                            victims_set.insert(fi);
                        }
                    }
                }
                for &fi in &active {
                    for &l in &d.flow_links[fi] {
                        contaminated[l as usize] = false;
                    }
                }
            }
            for &fi in &active {
                eject_count[d.flow_last[fi] as usize] = 0;
            }

            // time to next completion
            let mut dt = f64::INFINITY;
            for (idx, &fi) in active.iter().enumerate() {
                if rates[idx] > 0.0 {
                    dt = dt.min(remaining[fi] / rates[idx]);
                }
            }
            dt = dt.min(next_arrival - now);
            assert!(dt.is_finite() && dt >= 0.0, "bad dt {dt}");
            let dt = dt.max(1e-12);
            for (idx, &fi) in active.iter().enumerate() {
                remaining[fi] -= rates[idx] * dt;
            }
            now += dt;
            let cm = super::rounds::CostModel::new(self.topo);
            for &fi in &active {
                if remaining[fi] <= 1e-6 && !done[fi] {
                    done[fi] = true;
                    n_done += 1;
                    // completion includes the zero-load message latency
                    // and the queueing delay seen on entry
                    let tf = &flows[fi];
                    finish[fi] = now
                        + cm.msg_latency(&tf.rf.path, tf.rf.flow.bytes,
                            tf.rf.flow.buf)
                        + if queue_penalty[fi].is_nan() { 0.0 }
                          else { queue_penalty[fi] };
                }
            }
        }
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        DesResult {
            finish,
            makespan,
            contributors: contributors_set.len(),
            victims: victims_set.len(),
        }
    }

    /// Convenience: all flows start at t=0; returns per-flow durations.
    pub fn run_simultaneous(&self, flows: &[RoutedFlow]) -> FlowTimes {
        let timed: Vec<TimedFlow> = flows
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        let res = self.run(&timed);
        FlowTimes::from_vec(res.finish)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::fabric::{Flow, Router};

    fn setup() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    fn routed(topo: &Topology, flows: Vec<Flow>) -> Vec<RoutedFlow> {
        let mut r = Router::new(topo);
        flows
            .into_iter()
            .map(|f| RoutedFlow { path: r.route(&f), flow: f })
            .collect()
    }

    #[test]
    fn single_flow_rate_matches_issue_cap() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 1u64 << 30;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let res = sim.run_simultaneous(&fl);
        let rate = bytes as f64 / res.makespan;
        let cap = t.cfg.rank_issue_bw_host;
        assert!((rate - cap).abs() / cap < 0.02, "rate {rate} cap {cap}");
    }

    #[test]
    fn nic_sharing_halves_rates() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 1u64 << 30;
        // two ranks on the same NIC: fair share of nic_bw
        let fl = routed(
            &t,
            vec![Flow::new(0, 200, bytes), Flow::new(0, 208, bytes)],
        );
        let res = sim.run_simultaneous(&fl);
        let agg = 2.0 * bytes as f64 / res.makespan;
        assert!(agg <= t.cfg.nic_bw * 1.02, "aggregate {agg}");
        // but two ranks *do* push the NIC harder than one rank could
        assert!(agg > t.cfg.rank_issue_bw_host * 1.3);
    }

    #[test]
    fn incast_contributors_share_ejection_fairly() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 64u64 << 20;
        // 8-to-1 incast onto NIC 200
        let fl = routed(
            &t,
            (0..8).map(|i| Flow::new(i * 8, 200, bytes)).collect(),
        );
        let res = sim.run_simultaneous(&fl);
        let agg = 8.0 * bytes as f64 / res.makespan;
        assert!(agg <= t.cfg.nic_bw * 1.05, "incast exceeds ejection: {agg}");
    }

    #[test]
    fn victims_protected_with_congestion_mgmt() {
        let t = setup();
        let bytes = 16u64 << 20;
        // incast from group 1 NICs onto NIC 200 + one victim 0 -> 300
        let mut flows: Vec<Flow> =
            (0..6).map(|i| Flow::new(128 + i * 8, 200, bytes)).collect();
        flows.push(Flow::new(0, 300, bytes));
        let fl = routed(&t, flows);
        let on = DesSim::new(&t, DesOpts { congestion_mgmt: true, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let off = DesSim::new(&t, DesOpts { congestion_mgmt: false, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let victim_on = on.per_flow[6];
        let victim_off = off.per_flow[6];
        // victim may or may not share links; congestion mgmt must never be
        // worse, and when contaminated it is strictly better
        assert!(victim_on <= victim_off * 1.01,
            "victim with mgmt {victim_on} vs without {victim_off}");
    }

    #[test]
    fn congestion_off_hurts_crossing_victims() {
        let t = setup();
        let bytes = 16u64 << 20;
        // incast flows ejecting at NIC 200 (group 0... NIC200 is in group 3
        // region), victim shares the source group links
        let mut flows: Vec<Flow> =
            (0..8).map(|i| Flow::new(i * 8, 200, bytes)).collect();
        // victim from same source switch as contributor 0, different dest
        flows.push(Flow::new(1, 210, bytes));
        let fl = routed(&t, flows);
        let off = DesSim::new(&t, DesOpts { congestion_mgmt: false, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let on = DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        assert!(off.per_flow[8] >= on.per_flow[8],
            "victim must not be faster without congestion mgmt");
    }

    #[test]
    fn degraded_link_slows_flows() {
        let t = setup();
        let bytes = 64u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let healthy = DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        let mut degraded = HashMap::new();
        // half the lanes on every link of this path (§3.4 degraded mode)
        for l in &fl[0].path.links {
            degraded.insert(*l, 0.5);
        }
        let slow = DesSim::new(&t, DesOpts { degraded, ..DesOpts::default() })
            .run_simultaneous(&fl);
        assert!(slow.makespan > healthy.makespan * 1.05);
    }

    #[test]
    fn staggered_arrivals_respected() {
        let t = setup();
        let bytes = 16u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let sim = DesSim::new(&t, DesOpts::default());
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 1.0 }];
        let res = sim.run(&timed);
        assert!(res.finish[0] > 1.0);
    }
}
